"""Pallas kernels vs the pure-jnp oracle — the CORE correctness signal.

Hypothesis sweeps formats, multiplier widths, word counts and layer
shapes; every comparison is bit-exact (`array_equal`, not allclose:
the semantics are integer).
"""

import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # offline image — deterministic fallback
    from _hypothesis_compat import given, settings, st

from compile import defs
from compile.kernels import ref, softsimd

FORMATS = list(defs.FORMATS)
words = st.integers(min_value=0, max_value=defs.WORD_MASK)


def u64s(x):
    return jnp.asarray(np.asarray(x, dtype=np.uint64))


class TestMulKernel:
    @given(st.sampled_from(FORMATS), st.data())
    @settings(max_examples=25, deadline=None)
    def test_matches_dynamic_ref(self, bits, data):
        fmt = defs.SimdFormat(bits)
        y = data.draw(st.sampled_from([4, 8, bits]))
        half = 1 << (y - 1)
        m = data.draw(st.integers(-half, half - 1))
        n_words = softsimd.MUL_BLOCK * data.draw(st.sampled_from([1, 2]))
        rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
        ws = rng.integers(0, defs.WORD_MASK, size=n_words, dtype=np.uint64)
        shifts, signs = defs.plan_arrays(m, y)
        shifts = jnp.asarray(np.array(shifts, dtype=np.int32))
        signs = jnp.asarray(np.array(signs, dtype=np.int32))
        h = u64s([fmt.msb_mask])
        l = u64s([fmt.lsb_mask])
        got = softsimd.mul_packed_pallas(u64s(ws), shifts, signs, h, l)
        want = ref.mul_packed_dynamic_ref(u64s(ws), shifts, signs, h[0], l[0])
        assert np.array_equal(np.asarray(got), np.asarray(want))

    def test_scalar_pivot_once(self):
        """One direct kernel-vs-plain-int check (end of the pivot chain)."""
        fmt = defs.SimdFormat(8)
        m, y = 115, 8
        vals = list(range(-128, 128)) + [0] * (softsimd.MUL_BLOCK * 6 - 256)
        ws = defs.pack_stream(vals, fmt)
        shifts, signs = defs.plan_arrays(m, y)
        got = softsimd.mul_packed_pallas(
            u64s(ws),
            jnp.asarray(np.array(shifts, dtype=np.int32)),
            jnp.asarray(np.array(signs, dtype=np.int32)),
            u64s([fmt.msb_mask]),
            u64s([fmt.lsb_mask]),
        )
        got_lanes = defs.unpack_stream([int(w) for w in np.asarray(got)], fmt, len(vals))
        for v, g in zip(vals, got_lanes):
            assert g == defs.mul_scalar(v, m, 8, y), v


class TestLayerKernel:
    @given(st.data())
    @settings(max_examples=15, deadline=None)
    def test_matches_layer_ref(self, data):
        M = data.draw(st.sampled_from([1, 4, 16]))
        K = data.draw(st.sampled_from([8, 64]))
        N = data.draw(st.sampled_from([8, 16, 32]))
        seed = data.draw(st.integers(0, 2**31))
        rng = np.random.default_rng(seed)
        x = rng.integers(-128, 128, size=(M, K), dtype=np.int64).astype(np.int32)
        w = rng.integers(-128, 128, size=(K, N), dtype=np.int64)
        shifts = np.zeros((K, N, defs.OPS_MAX), dtype=np.int32)
        signs = np.zeros((K, N, defs.OPS_MAX), dtype=np.int32)
        for i in range(K):
            for j in range(N):
                s, g = defs.plan_arrays(int(w[i, j]), 8)
                shifts[i, j], signs[i, j] = s, g
        got = softsimd.layer_pallas(jnp.asarray(x), jnp.asarray(shifts), jnp.asarray(signs))
        want = ref.layer_ref(jnp.asarray(x), jnp.asarray(shifts), jnp.asarray(signs))
        assert np.array_equal(np.asarray(got), np.asarray(want))

    def test_tile_boundaries_exact(self):
        """Neuron tiles must not bleed into each other."""
        M, K, N = 2, 4, 16
        x = np.full((M, K), 100, dtype=np.int32)
        w = np.zeros((K, N), dtype=np.int64)
        w[:, 0] = 127
        w[:, N - 1] = -128
        shifts = np.zeros((K, N, defs.OPS_MAX), dtype=np.int32)
        signs = np.zeros((K, N, defs.OPS_MAX), dtype=np.int32)
        for i in range(K):
            for j in range(N):
                s, g = defs.plan_arrays(int(w[i, j]), 8)
                shifts[i, j], signs[i, j] = s, g
        got = np.asarray(
            softsimd.layer_pallas(jnp.asarray(x), jnp.asarray(shifts), jnp.asarray(signs))
        )
        want = np.asarray(
            ref.layer_ref(jnp.asarray(x), jnp.asarray(shifts), jnp.asarray(signs))
        )
        assert np.array_equal(got, want)
        assert (got[:, 1 : N - 1] == 0).all()
