//! Quantized neural-network execution on the Soft SIMD semantics.
//!
//! `weights` loads the AOT-baked model; `conv` adds Conv2D layers and
//! their im2col lowering onto the same packed matmul core (DESIGN.md
//! §12); `exec` provides the scalar-int reference forward passes (the
//! semantic pivot shared with
//! `python/compile/model.py::mlp_forward_int`) that the packed serving
//! engine must match bit-exactly.

pub mod conv;
pub mod exec;
pub mod weights;

pub use conv::{conv_forward_row, ConvLayer, ConvShape, LayerOp};
pub use exec::{
    mlp_forward_batch, mlp_forward_row, mlp_forward_row_mixed, requantize_activation,
    stack_forward_row,
};
pub use weights::{load_weight_file, quantize_stack, uniform_schedule, LayerPrecision, QuantLayer};
