//! Combinational multipliers — the heart of the Hard SIMD baselines
//! (Section IV-A).
//!
//! * `build_signed_mul` — a two's-complement `b×b` multiplier: partial
//!   products with Baugh-Wooley-style sign rows, Wallace (column 3:2)
//!   reduction, and a carry-select final adder — the structure synthesis
//!   produces for a combinational multiplier under a tight clock.
//! * `simd_multiplier_bank(fmts, isolate)` — the Hard SIMD datapath: one
//!   lane-multiplier bank per supported sub-word width behind a shared
//!   operand bus, with a one-hot product select.
//!
//!   **Operand isolation** (`isolate`): the {8,16} baseline gates each
//!   bank's operands with its format select, so inactive banks are
//!   quiet. The 5-format flexible baseline shares the operand bus
//!   *without* isolation — with five banks the isolation AND + format
//!   decode lands on the multiplier critical path and its area/routing
//!   overhead defeats the purpose; the result is that every bank
//!   switches on every cycle, which is precisely why the paper finds
//!   the flexible Hard SIMD consistently *worse* than the lean one
//!   (Fig. 10) and why Soft SIMD's advantage peaks at small sub-words
//!   (Fig. 9). Documented in DESIGN.md §2.
//!
//! Products are returned in the multiplicand's `Q1.(b-1)` format: the
//! `2b`-bit product `x·m` truncated to bits `(b-1)..(2b-1)`.

use super::build::NetBuilder;
use super::gate::{Netlist, NodeId};
use crate::bits::format::SimdFormat;

/// Carry-select adder over two equal-width operands (no sub-word
/// boundaries — used as a multiplier's final CPA).
fn carry_select_add(b: &mut NetBuilder, x: &[NodeId], y: &[NodeId], block: usize) -> Vec<NodeId> {
    let n = x.len();
    assert_eq!(y.len(), n);
    let mut out = Vec::with_capacity(n);
    let mut blk_cin: Option<NodeId> = None;
    let mut start = 0usize;
    while start < n {
        let end = (start + block).min(n);
        let mut variants: Vec<(Vec<NodeId>, NodeId)> = vec![];
        for assumed in 0..2u8 {
            let mut sums = vec![];
            let mut carry = if assumed == 0 { b.zero() } else { b.one() };
            for i in start..end {
                let (s, c) = b.full_adder(x[i], y[i], carry);
                sums.push(s);
                carry = c;
            }
            variants.push((sums, carry));
        }
        let (s0, c0) = variants.swap_remove(0);
        let (s1, c1) = variants.swap_remove(0);
        match blk_cin {
            None => {
                out.extend_from_slice(&s0);
                blk_cin = Some(c0);
            }
            Some(sel) => {
                for i in 0..s0.len() {
                    out.push(b.mux2(sel, s0[i], s1[i]));
                }
                blk_cin = Some(b.mux2(sel, c0, c1));
            }
        }
        start = end;
    }
    out
}

/// Emit a signed `b×b` multiplier; returns the `2b`-bit product nets.
///
/// Rows: `P = Σ_{j<b-1} m_j·A·2^j − m_{b-1}·A·2^{b-1}` with `A`
/// sign-extended; the subtracted row enters as complement + carry bit.
/// All partial-product bits are dropped into per-column stacks and
/// reduced 3:2 (Wallace); the remaining two rows go through a
/// carry-select adder.
pub fn build_signed_mul(b: &mut NetBuilder, a: &[NodeId], m: &[NodeId]) -> Vec<NodeId> {
    let n = a.len();
    assert_eq!(m.len(), n);
    let width = 2 * n;
    // Per-column bit stacks.
    let mut cols: Vec<Vec<NodeId>> = vec![vec![]; width];
    // Sign-extend A to `width` bits.
    let a_ext: Vec<NodeId> = (0..width).map(|i| a[i.min(n - 1)]).collect();
    for j in 0..n {
        let is_sign_row = j == n - 1;
        if is_sign_row {
            // Subtract row: complement (gated) + carry-in 1 (gated by m_j).
            for i in j..width {
                let bit = a_ext[i - j];
                let nb = b.not(bit);
                let pp = b.and2(m[j], nb);
                cols[i].push(pp);
            }
            // +1 of the two's complement, only when the row is active.
            let inj = b.buf(m[j]);
            cols[j].push(inj);
        } else {
            for i in j..width {
                let bit = a_ext[i - j];
                let pp = b.and2(bit, m[j]);
                cols[i].push(pp);
            }
        }
    }
    // Wallace 3:2 reduction until every column holds ≤ 2 bits.
    loop {
        let max_h = cols.iter().map(Vec::len).max().unwrap();
        if max_h <= 2 {
            break;
        }
        let mut next: Vec<Vec<NodeId>> = vec![vec![]; width];
        for (i, stack) in cols.iter().enumerate() {
            let mut k = 0;
            while stack.len() - k >= 3 {
                let (s, c) = b.full_adder(stack[k], stack[k + 1], stack[k + 2]);
                next[i].push(s);
                if i + 1 < width {
                    next[i + 1].push(c);
                }
                k += 3;
            }
            for &bit in &stack[k..] {
                next[i].push(bit);
            }
        }
        cols = next;
    }
    // Final CPA over the two remaining rows.
    let zero = b.zero();
    let row0: Vec<NodeId> = cols.iter().map(|s| s.first().copied().unwrap_or(zero)).collect();
    let row1: Vec<NodeId> = cols.iter().map(|s| s.get(1).copied().unwrap_or(zero)).collect();
    carry_select_add(b, &row0, &row1, 4)
}

/// Standalone `b×b` signed multiplier netlist.
/// Inputs: a[b], m[b]; outputs: p[2b].
pub fn signed_multiplier(bits: u32) -> Netlist {
    let mut nb = NetBuilder::new(&format!("mul{bits}x{bits}"));
    let a = nb.inputs(bits as usize);
    let m = nb.inputs(bits as usize);
    let p = build_signed_mul(&mut nb, &a, &m);
    nb.outputs(&p);
    nb.finish()
}

/// The Hard SIMD multiplier datapath for a format set.
///
/// Inputs: a[48] (packed multiplicands), mvec[48] (packed multipliers,
/// same format), fmt_onehot[#fmts]. Outputs: p[48] (packed `Q1.(b-1)`
/// products). See the module docs for the `isolate` design decision.
pub fn simd_multiplier_bank(fmts: &[u32], isolate: bool) -> Netlist {
    let mut nb = NetBuilder::new(&format!("hardsimd_mul_{fmts:?}"));
    let a = nb.inputs(48);
    let m = nb.inputs(48);
    let sel = nb.inputs(fmts.len());
    let mut per_bank_out: Vec<Vec<NodeId>> = vec![];
    for (fi, &bits) in fmts.iter().enumerate() {
        let fmt = SimdFormat::new(bits);
        let mut bank_out: Vec<NodeId> = Vec::with_capacity(48);
        for lane in 0..fmt.lanes() {
            let base = (lane * bits) as usize;
            let (ga, gm): (Vec<NodeId>, Vec<NodeId>) = if isolate {
                (
                    (0..bits as usize).map(|i| nb.and2(a[base + i], sel[fi])).collect(),
                    (0..bits as usize).map(|i| nb.and2(m[base + i], sel[fi])).collect(),
                )
            } else {
                (
                    (0..bits as usize).map(|i| a[base + i]).collect(),
                    (0..bits as usize).map(|i| m[base + i]).collect(),
                )
            };
            let p = build_signed_mul(&mut nb, &ga, &gm);
            // Q1 truncation: product bits (b-1)..(2b-1).
            bank_out.extend_from_slice(&p[(bits - 1) as usize..(2 * bits - 1) as usize]);
        }
        per_bank_out.push(bank_out);
    }
    for j in 0..48 {
        let vals: Vec<NodeId> = per_bank_out.iter().map(|o| o[j]).collect();
        let sels: Vec<NodeId> = (0..fmts.len()).map(|fi| sel[fi]).collect();
        let out = nb.onehot_mux(&sels, &vals);
        nb.output(out);
    }
    nb.finish()
}

/// The shared **divisible array** — the Hard SIMD *cost* netlist
/// (DESIGN.md §2).
///
/// A real flexible SIMD multiplier is not five parallel banks: it is one
/// array, dimensioned for the widest format (3 lanes of 16×16 here),
/// whose partial-product/carry network is partitioned at runtime.
/// Consequences this netlist models structurally:
///
/// * **No operand isolation is possible** — every multiplication swings
///   the whole array, whatever the sub-word width. (This is why Soft
///   SIMD's advantage peaks at small widths, Fig. 9.)
/// * **Each supported partition adds gating/realignment cells** that
///   both occupy area and toggle with the data. Power-of-two partitions
///   (8, 4) gate only boundary diagonals; widths that do not divide the
///   16-bit grid (6, and 12 spanning lane pairs) need per-cell masking
///   and operand realignment muxes — far more hardware. (This is why the
///   flexible Hard SIMD is consistently *worse* than the {8,16} one,
///   Fig. 10.)
///
/// The 16-bit mode's product outputs are functionally exact (verified in
/// tests); narrower modes' *values* are produced by [`hard_product`] in
/// the architecture model — this netlist is the area/energy carrier.
/// Gating-cell populations per partition are structural approximations
/// (fractions of the PP-cell count) documented inline.
pub fn divisible_array(fmts: &[u32]) -> Netlist {
    let mut nb = NetBuilder::new(&format!("hardsimd_divisible_{fmts:?}"));
    let a = nb.inputs(48);
    let m = nb.inputs(48);
    let sel = nb.inputs(fmts.len());
    // Base: 3 lanes of 16×16.
    let mut outs = vec![];
    for lane in 0..3usize {
        let base = lane * 16;
        let al: Vec<NodeId> = (0..16).map(|i| a[base + i]).collect();
        let ml: Vec<NodeId> = (0..16).map(|i| m[base + i]).collect();
        let p = build_signed_mul(&mut nb, &al, &ml);
        outs.extend_from_slice(&p[15..31]); // Q1 truncation at b = 16
    }
    // Partition overhead per supported format (fraction of the ~256
    // PP positions per lane that need gating/realignment):
    //   8: boundary diagonals only                     → 0.25
    //   4: three boundaries per lane                   → 0.50
    //   6: does not divide the 16-grid — per-cell mask
    //      + operand realignment muxes                 → 1.20
    //  12: spans lane pairs — cross-lane carry gating
    //      + realignment                               → 1.10
    for (fi, &f) in fmts.iter().enumerate() {
        let frac = match f {
            16 => 0.0,
            8 => 0.25,
            4 => 0.50,
            6 => 1.20,
            12 => 1.10,
            _ => 0.5,
        };
        let n_gates = (3.0 * 256.0 * frac) as usize;
        for g in 0..n_gates {
            // Real cells wired to real data so they toggle: a PP-like
            // term gated by the format select.
            let x = a[(g * 7 + fi) % 48];
            let y = m[(g * 13 + fi * 5) % 48];
            let pp = nb.and2(x, y);
            let _gated = nb.and2(pp, sel[fi]);
        }
        // Realignment muxes for non-dividing widths (operand + product
        // renormalization networks).
        if f == 6 || f == 12 {
            for i in 0..96 {
                let _mx = nb.mux2(sel[fi], a[i % 48], a[(i + f as usize) % 48]);
            }
        }
    }
    nb.outputs(&outs);
    nb.finish()
}

/// Reference semantics of the Hard SIMD product (single truncation).
pub fn hard_product(x_raw: i64, m_raw: i64, bits: u32) -> i64 {
    let full = x_raw * m_raw; // exact in i64 for ≤16-bit operands
    crate::bits::fixed::sign_extend(
        ((full >> (bits - 1)) as u64) & ((1u64 << bits) - 1),
        bits,
    )
}

/// Drive the bank for one cycle.
pub fn drive_bank(
    sim: &mut super::sim::Simulator,
    net: &Netlist,
    fmts: &[u32],
    a: u64,
    m: u64,
    fmt: SimdFormat,
) -> u64 {
    let mut ins = Vec::with_capacity(96 + fmts.len());
    for i in 0..48 {
        ins.push((a >> i) & 1 != 0);
    }
    for i in 0..48 {
        ins.push((m >> i) & 1 != 0);
    }
    for &f in fmts {
        ins.push(f == fmt.bits);
    }
    sim.set_inputs(&ins);
    sim.eval(net);
    sim.output_u64(net, 0, 48)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::fixed::sign_extend;
    use crate::bits::pack::{pack, unpack};
    use crate::rtl::sim::Simulator;
    use crate::rtl::timing::depth;
    use crate::workload::synth::XorShift64;

    #[test]
    fn four_by_four_exhaustive() {
        let net = signed_multiplier(4);
        let mut sim = Simulator::new(&net);
        for x in -8i64..8 {
            for m in -8i64..8 {
                let mut ins = vec![];
                for i in 0..4 {
                    ins.push((x >> i) & 1 != 0);
                }
                for i in 0..4 {
                    ins.push((m >> i) & 1 != 0);
                }
                sim.set_inputs(&ins);
                sim.eval(&net);
                let p = sign_extend(sim.output_u64(&net, 0, 8), 8);
                assert_eq!(p, x * m, "{x} × {m}");
            }
        }
    }

    #[test]
    fn eight_by_eight_sampled() {
        let net = signed_multiplier(8);
        let mut sim = Simulator::new(&net);
        let mut rng = XorShift64::new(0x4A11);
        for _ in 0..500 {
            let x = rng.q_raw(8);
            let m = rng.q_raw(8);
            let mut ins = vec![];
            for i in 0..8 {
                ins.push((x >> i) & 1 != 0);
            }
            for i in 0..8 {
                ins.push((m >> i) & 1 != 0);
            }
            sim.set_inputs(&ins);
            sim.eval(&net);
            let p = sign_extend(sim.output_u64(&net, 0, 16), 16);
            assert_eq!(p, x * m, "{x} × {m}");
        }
    }

    #[test]
    fn wallace_structure_is_shallow() {
        let net = signed_multiplier(16);
        // Wallace + carry-select CPA: far shallower than a linear array.
        assert!(depth(&net) < 80, "depth {}", depth(&net));
    }

    #[test]
    fn bank_matches_hard_product_semantics() {
        for (fmts, isolate) in [(vec![8u32, 16], true), (vec![4, 6, 8, 12, 16], false)] {
            let net = simd_multiplier_bank(&fmts, isolate);
            let mut sim = Simulator::new(&net);
            let mut rng = XorShift64::new(0xBA4C);
            for &bits in &fmts {
                let fmt = SimdFormat::new(bits);
                for _ in 0..40 {
                    let xs: Vec<i64> = (0..fmt.lanes()).map(|_| rng.q_raw(bits)).collect();
                    let ms: Vec<i64> = (0..fmt.lanes()).map(|_| rng.q_raw(bits)).collect();
                    let got =
                        drive_bank(&mut sim, &net, &fmts, pack(&xs, fmt), pack(&ms, fmt), fmt);
                    let want: Vec<i64> = xs
                        .iter()
                        .zip(&ms)
                        .map(|(&x, &m)| hard_product(x, m, bits))
                        .collect();
                    assert_eq!(unpack(got, fmt), want, "fmt {fmt}");
                }
            }
        }
    }

    #[test]
    fn flexible_bank_is_bigger_than_two_format_bank() {
        let flex = simd_multiplier_bank(&[4, 6, 8, 12, 16], false);
        let two = simd_multiplier_bank(&[8, 16], true);
        assert!(flex.logic_cells() > two.logic_cells());
        let ratio = flex.logic_cells() as f64 / two.logic_cells() as f64;
        assert!((1.3..3.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn unisolated_bank_switches_in_narrow_modes() {
        // Flexible bank at 4-bit: all five banks toggle (shared bus).
        let fmts = [4u32, 6, 8, 12, 16];
        let net = simd_multiplier_bank(&fmts, false);
        let mut sim = Simulator::new(&net);
        let mut rng = XorShift64::new(0x616C);
        let fmt4 = SimdFormat::new(4);
        // warm up
        drive_bank(&mut sim, &net, &fmts, rng.word(), rng.word(), fmt4);
        sim.reset_counters();
        for _ in 0..20 {
            drive_bank(&mut sim, &net, &fmts, rng.word(), rng.word(), fmt4);
        }
        let toggles_4bit = sim.toggles;
        // Isolated two-format bank at 8-bit for comparison.
        let fmts2 = [8u32, 16];
        let net2 = simd_multiplier_bank(&fmts2, true);
        let mut sim2 = Simulator::new(&net2);
        let fmt8 = SimdFormat::new(8);
        drive_bank(&mut sim2, &net2, &fmts2, rng.word(), rng.word(), fmt8);
        sim2.reset_counters();
        for _ in 0..20 {
            drive_bank(&mut sim2, &net2, &fmts2, rng.word(), rng.word(), fmt8);
        }
        // The flexible bank burns more switching on an *easier* job.
        assert!(toggles_4bit > sim2.toggles, "{toggles_4bit} vs {}", sim2.toggles);
    }
}
