//! The coordinator: request intake → dynamic batcher → PE worker pool.
//!
//! Leader thread owns the batcher; worker threads own one
//! [`PackedMlpEngine`] each (the near-memory PEs). Channels carry formed
//! batches out and scattered responses back — the same leader/worker
//! shape a vLLM-style router uses, scaled to this paper's accelerator.

use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use super::batcher::{Batch, Batcher};
use super::cost::CostTable;
use super::engine::PackedMlpEngine;
use super::metrics::Metrics;
use crate::bits::format::SimdFormat;
use crate::nn::weights::QuantLayer;

/// An inference request: rows of quantized activations.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub rows: Vec<Vec<i64>>,
}

/// Its response: per-row `Q1.(acc_bits-1)` logits.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<Vec<i64>>,
}

enum WorkerMsg {
    Work(Batch),
    Stop,
}

/// The running coordinator.
pub struct Coordinator {
    batcher: Batcher,
    tx_work: Vec<Sender<WorkerMsg>>,
    rx_done: Receiver<Vec<Response>>,
    workers: Vec<JoinHandle<()>>,
    next_worker: usize,
    pub metrics: Arc<Metrics>,
    in_flight: usize,
}

impl Coordinator {
    /// Spawn `n_pes` worker PEs serving the given model.
    pub fn start(
        layers: Vec<QuantLayer>,
        in_bits: u32,
        acc_bits: u32,
        n_pes: usize,
        target_rows: usize,
        cost: CostTable,
    ) -> Coordinator {
        let metrics = Arc::new(Metrics::default());
        let (tx_done, rx_done) = channel::<Vec<Response>>();
        let mut tx_work = vec![];
        let mut workers = vec![];
        let cost = Arc::new(cost);
        for _ in 0..n_pes {
            let (tx, rx) = channel::<WorkerMsg>();
            tx_work.push(tx);
            let done = tx_done.clone();
            let m = Arc::clone(&metrics);
            let c = Arc::clone(&cost);
            let engine = PackedMlpEngine::new(layers.clone(), in_bits, acc_bits);
            workers.push(std::thread::spawn(move || {
                worker_loop(engine, rx, done, m, c);
            }));
        }
        Coordinator {
            batcher: Batcher::new(target_rows, 4),
            tx_work,
            rx_done,
            workers,
            next_worker: 0,
            metrics,
            in_flight: 0,
        }
    }

    fn dispatch(&mut self, batch: Batch) {
        let w = self.next_worker % self.tx_work.len();
        self.next_worker += 1;
        self.in_flight += 1;
        self.tx_work[w]
            .send(WorkerMsg::Work(batch))
            .expect("worker alive");
    }

    /// Submit a request (may trigger a batch dispatch).
    pub fn submit(&mut self, req: Request) {
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        if let Some(batch) = self.batcher.push(req) {
            self.dispatch(batch);
        }
    }

    /// Flush stragglers and wait for every response.
    pub fn drain(&mut self) -> Vec<Response> {
        if let Some(batch) = self.batcher.flush() {
            self.dispatch(batch);
        }
        let mut out = vec![];
        while self.in_flight > 0 {
            let mut rs = self.rx_done.recv().expect("worker response");
            out.append(&mut rs);
            self.in_flight -= 1;
        }
        out.sort_by_key(|r| r.id);
        out
    }

    /// Stop workers and join.
    pub fn shutdown(mut self) {
        for tx in &self.tx_work {
            let _ = tx.send(WorkerMsg::Stop);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    engine: PackedMlpEngine,
    rx: Receiver<WorkerMsg>,
    done: Sender<Vec<Response>>,
    metrics: Arc<Metrics>,
    cost: Arc<CostTable>,
) {
    let in_fmt = SimdFormat::new(engine.in_bits);
    while let Ok(WorkerMsg::Work(batch)) = rx.recv() {
        let t0 = Instant::now();
        // Gather rows, run packed, scatter back per request.
        let rows: Vec<Vec<i64>> = batch
            .requests
            .iter()
            .flat_map(|r| r.rows.iter().cloned())
            .collect();
        let (logits, stats) = engine.forward_batch(&rows);
        let ns = t0.elapsed().as_nanos() as u64;
        let pj = cost.energy_pj(stats.s1_cycles, in_fmt, stats.s2_passes);
        metrics.add_batch(rows.len() as u64, stats, pj, ns);
        let mut responses = vec![];
        let mut offset = 0;
        for req in &batch.requests {
            let n = req.rows.len();
            responses.push(Response {
                id: req.id,
                logits: logits[offset..offset + n].to_vec(),
            });
            offset += n;
        }
        done.send(responses).expect("leader alive");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::exec::mlp_forward_row;
    use crate::workload::synth::XorShift64;

    fn layers(rng: &mut XorShift64) -> Vec<QuantLayer> {
        vec![
            QuantLayer::new(
                (0..8).map(|_| (0..5).map(|_| rng.q_raw(8)).collect()).collect(),
                8,
            ),
            QuantLayer::new(
                (0..5).map(|_| (0..3).map(|_| rng.q_raw(8)).collect()).collect(),
                8,
            ),
        ]
    }

    fn tiny_cost() -> CostTable {
        CostTable {
            mhz: 1000.0,
            s1_cycle_pj: crate::bits::format::FORMATS.iter().map(|&b| (b, 1.0)).collect(),
            s2_pass_pj: 0.5,
            area_um2: 1000.0,
        }
    }

    #[test]
    fn coordinator_round_trip_matches_reference() {
        let mut rng = XorShift64::new(0xC00D);
        let ls = layers(&mut rng);
        let mut coord = Coordinator::start(ls.clone(), 8, 16, 2, 6, tiny_cost());
        let reqs: Vec<Request> = (0..9u64)
            .map(|id| Request {
                id,
                rows: (0..(1 + (id as usize % 3)))
                    .map(|_| (0..8).map(|_| rng.q_raw(8)).collect())
                    .collect(),
            })
            .collect();
        let expected: Vec<Vec<Vec<i64>>> = reqs
            .iter()
            .map(|r| r.rows.iter().map(|row| mlp_forward_row(row, &ls, 8, 16)).collect())
            .collect();
        for r in reqs {
            coord.submit(r);
        }
        let responses = coord.drain();
        assert_eq!(responses.len(), 9);
        for resp in &responses {
            assert_eq!(resp.logits, expected[resp.id as usize], "request {}", resp.id);
        }
        assert!(coord.metrics.subword_mults.load(Ordering::Relaxed) > 0);
        coord.shutdown();
    }

    #[test]
    fn batching_groups_requests() {
        let mut rng = XorShift64::new(0xBA7);
        let ls = layers(&mut rng);
        let mut coord = Coordinator::start(ls, 8, 16, 1, 12, tiny_cost());
        for id in 0..12u64 {
            coord.submit(Request {
                id,
                rows: vec![(0..8).map(|_| rng.q_raw(8)).collect()],
            });
        }
        let responses = coord.drain();
        assert_eq!(responses.len(), 12);
        let batches = coord.metrics.batches.load(Ordering::Relaxed);
        assert!(batches <= 2, "expected ≤2 batches, got {batches}");
        coord.shutdown();
    }
}
