//! 28nm-LP-class standard-cell characterization.
//!
//! Values are public-domain-plausible figures for a 28nm low-power
//! process at 0.9 V, nominal corner (DESIGN.md §6): they set the
//! *absolute* scale (so pJ numbers land in the paper's Fig. 8 range);
//! every comparison in the evaluation depends only on ratios that come
//! from real netlist structure and real switching activity.

use crate::rtl::gate::CellKind;

/// Per-kind standard-cell costs.
#[derive(Debug, Clone, Copy)]
pub struct CellCosts {
    /// Area in NAND2 equivalents.
    pub area_eq: f64,
    /// Dynamic energy per output toggle, fJ (incl. local interconnect).
    pub toggle_fj: f64,
}

/// Technology parameters.
#[derive(Debug, Clone, Copy)]
pub struct TechParams {
    /// NAND2 footprint, µm².
    pub nand2_um2: f64,
    /// Nominal per-level delay, ps (FO4-ish at nominal drive).
    pub gate_delay_ps: f64,
    /// DFF area, NAND2-eq.
    pub dff_area_eq: f64,
    /// DFF clock (internal) energy per clocked cycle, fJ.
    pub dff_clk_fj: f64,
    /// DFF extra energy per *written* (toggled) bit, fJ.
    pub dff_write_fj: f64,
    /// Leakage per NAND2-eq, nW.
    pub leak_nw_per_eq: f64,
    /// Supply-referenced scale factor applied to all toggle energies.
    pub energy_scale: f64,
}

pub const TECH28: TechParams = TechParams {
    nand2_um2: 0.49,
    gate_delay_ps: 32.0,
    dff_area_eq: 4.5,
    dff_clk_fj: 1.1,
    dff_write_fj: 2.6,
    leak_nw_per_eq: 0.35,
    energy_scale: 1.0,
};

/// Costs per cell kind.
pub fn cell_costs(kind: CellKind) -> CellCosts {
    match kind {
        CellKind::Input | CellKind::Const0 | CellKind::Const1 => {
            CellCosts { area_eq: 0.0, toggle_fj: 0.0 }
        }
        CellKind::Inv => CellCosts { area_eq: 0.67, toggle_fj: 0.55 },
        CellKind::Buf => CellCosts { area_eq: 1.0, toggle_fj: 0.75 },
        CellKind::And2 | CellKind::Or2 => CellCosts { area_eq: 1.33, toggle_fj: 1.0 },
        CellKind::Nand2 | CellKind::Nor2 => CellCosts { area_eq: 1.0, toggle_fj: 0.9 },
        CellKind::Xor2 | CellKind::Xnor2 => CellCosts { area_eq: 2.33, toggle_fj: 1.9 },
        CellKind::Mux2 => CellCosts { area_eq: 2.33, toggle_fj: 1.7 },
    }
}

/// Zero-delay simulation sees no glitches; these block-class factors
/// restore the energy glitching adds in real silicon (array multipliers
/// glitch notoriously — 2–3× is the published range; short reconvergent
/// mux networks barely glitch).
#[derive(Debug, Clone, Copy)]
pub enum GlitchClass {
    MultiplierArray,
    AdderChain,
    MuxNetwork,
}

impl GlitchClass {
    pub fn factor(self) -> f64 {
        match self {
            GlitchClass::MultiplierArray => 2.4,
            GlitchClass::AdderChain => 1.30,
            GlitchClass::MuxNetwork => 1.08,
        }
    }
}

/// The synthesis-pressure model (DESIGN.md §6): a block of structural
/// depth `levels` synthesized at period `T = 1/f` is up-sized by
///
///   σ = 1                          for c ≤ 0.65
///   σ = 1 + 1.35·(c − 0.65)^1.6    otherwise,  c = levels·d₀ / T
///
/// capped at σ ≤ 3.5 (beyond that a real flow restructures — modeled
/// explicitly by the adder-variant switch in `model.rs`). Dynamic energy
/// follows partially (bigger drivers, more wire): factor
/// `1 + 0.55·(σ − 1)`; leakage follows σ fully.
pub fn sizing(levels: u32, mhz: f64, p: &TechParams) -> f64 {
    let period_ps = 1.0e6 / mhz;
    let c = levels as f64 * p.gate_delay_ps / period_ps;
    let sigma = if c <= 0.65 { 1.0 } else { 1.0 + 1.35 * (c - 0.65).powf(1.6) };
    sigma.min(3.5)
}

pub fn energy_factor(sigma: f64) -> f64 {
    1.0 + 0.55 * (sigma - 1.0)
}

/// The timing constraints evaluated in the paper (Fig. 6 uses 200 MHz
/// and 1 GHz; Fig. 8 adds intermediate points).
pub const MHZ_POINTS: [f64; 3] = [200.0, 500.0, 1000.0];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizing_is_monotone_in_frequency() {
        for levels in [10u32, 30, 60] {
            let s200 = sizing(levels, 200.0, &TECH28);
            let s500 = sizing(levels, 500.0, &TECH28);
            let s1000 = sizing(levels, 1000.0, &TECH28);
            assert!(s200 <= s500 && s500 <= s1000, "{s200} {s500} {s1000}");
        }
    }

    #[test]
    fn shallow_blocks_do_not_grow() {
        // A 8-level block at 1 GHz: c = 8·32/1000 = 0.26 → σ = 1.
        assert_eq!(sizing(8, 1000.0, &TECH28), 1.0);
    }

    #[test]
    fn deep_blocks_grow_hard_at_1ghz() {
        let s = sizing(40, 1000.0, &TECH28);
        assert!(s > 1.3, "{s}");
    }

    #[test]
    fn sizing_caps() {
        assert!(sizing(300, 1000.0, &TECH28) <= 3.5);
    }
}
