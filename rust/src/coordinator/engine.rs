//! Packed execution of interleaved conv + dense stacks on a simulated
//! PE.
//!
//! Layer semantics are pinned in DESIGN.md §4/§10/§12 and must match
//! `nn::exec::stack_forward_row` bit-exactly — the integration tests
//! enforce it. The engine packs the *batch* dimension into sub-words:
//! every sample's activation `x[m][k]` for a fixed `k` shares the same
//! weight multiplier `w[k][n]`, which is exactly the "one multiplier,
//! several multiplicands" pattern of Section III-B. A Conv2D layer
//! folds its output pixels into that same packed dimension (im2col,
//! DESIGN.md §12): each output pixel of each image is one patch row,
//! so one kernel weight's CSD plan streams over `m · out_h · out_w`
//! sub-words per word column — convolution is where the sub-word
//! packing wins compound.
//!
//! The engine is **format-polymorphic**: each layer executes at its own
//! activation/accumulator format pair from the model's precision
//! schedule, so lane occupancy changes per layer (12 sub-words per word
//! at 4-bit, 6 at 8-bit, 3 at 16-bit) and words-per-column, Stage-1
//! cycle billing and Stage-2 pass billing are all per-layer. At every
//! layer boundary the activation stream is converted through the
//! Stage-2 crossbar chain precompiled in the model (`boundary_chain`),
//! after the activation unit applies ReLU — the paper's "changing the
//! bitwidth of sub-words at run-time" exercised on the serving path.
//!
//! **Execution strategy (DESIGN.md §11/§12).** The hot path is
//! [`PackedEngine::forward_batch_into`]: an allocation-free,
//! cache-friendly core that
//! * executes the model's flattened micro-op arena
//!   ([`crate::csd::flat::PlanArena`]) via [`Stage1::run_flat`] — one
//!   byte per cycle, the `k` plans feeding an output column adjacent, no
//!   `MulPlan`/`Arc` in the inner loop;
//! * keeps every intermediate in a caller-owned [`EngineScratch`]
//!   (packed activation words, the weight-stationary accumulator block,
//!   product/boundary staging, the scalar feature-map staging of conv
//!   boundaries, the im2col gather column), so steady-state serving
//!   performs **zero heap allocations** after the first batch warms the
//!   buffers — the counting-allocator integration test enforces this
//!   for dense and conv schedules alike;
//! * keeps activations *packed* across dense→dense boundaries (word
//!   level [`crate::bits::swar::swar_relu`] +
//!   [`crate::pipeline::stage2::repack_hop_into`] whole-stream hops);
//!   conv-adjacent boundaries additionally stage the converted stream
//!   as scalars in [`EngineScratch::fmap`] so the next layer's im2col
//!   (or flatten) gather can read features at arbitrary offsets —
//!   patch columns are written straight back into the packed column
//!   buffer, never through per-patch `Vec`s;
//! * fuses the doubling-path widen+accumulate per product word.
//!
//! Billing is **independent of execution strategy**: `EngineStats` is
//! derived from the Stage-1 datapath's own cycle counters
//! ([`Stage1::take_counters`] — one source of truth, no re-billing via
//! `plan.cycles()`) and counts exactly what the pre-refactor engine
//! counted for the same work; the property tests pin the formulas.
//!
//! **Activation zero-skipping (DESIGN.md §18).** Stage-1 is
//! data-dependent: a packed operand word that is all zero multiplies to
//! zero under any plan, so the engine elides that plan execution
//! entirely — bit-exact, because the elided accumulate is the identity.
//! The forgone work is tallied in `EngineStats::skipped_*`, making the
//! static cost certificate a certified **upper bound** on the Stage-1
//! bill with an exact conservation law (`certificate == executed +
//! skipped`, per format bucket) that `billaudit` checks every batch;
//! accumulate and Stage-2 billing stay value-independent. Post-ReLU
//! feature maps are where whole words go zero in practice — the
//! paper's zero-skipping claim exercised on the batch-packed axis.
//! [`PackedEngine::with_zero_skip`] turns it off for A/B baselines.
//! Boundary conversions are billed identically whether the stream stays
//! packed or is staged scalar — the crossbar does the same work either
//! way; the im2col gather/scatter itself is near-memory data staging
//! and is billed no datapath cycles, exactly like the first layer's
//! batch pack (DESIGN.md §12).
//!
//! **Execution backends (DESIGN.md §16).** Under `--features simd` the
//! same core runs the flat micro-op stream on [`TILE`] packed words per
//! instruction through the host-vector kernels of
//! [`crate::bits::swarx`] (AVX2 when the host has it, a portable
//! unrolled kernel otherwise), with the scalar loop covering the
//! sub-tile tail of every column. The backend choice changes **nothing
//! observable**: outputs are bit-exact and `EngineStats` is identical
//! to the scalar core (and therefore to the PR 7 cost certificate),
//! because billing is derived from the micro-op stream — the same
//! bytes execute on either backend, only more words per dispatch.
//! `lanecheck` builds pin the scalar path at compile time (the
//! sanitizer's hooks are word-at-a-time); `billaudit` audits the
//! vector path unchanged. [`PackedEngine::forward_batch_into_scalar`]
//! keeps the scalar core reachable in-process as the differential
//! baseline.
//!
//! [`TILE`]: crate::bits::swarx::TILE
//!
//! The engine owns no weights and compiles no plans: it executes a
//! shared immutable [`CompiledModel`] (DESIGN.md §8). Batches are padded
//! with zero rows up to the model's batch quantum (the LCM of every
//! layer's lane counts; 6 for the uniform 8→16 schedule) so every packed
//! word runs full at every layer; pad rows are dropped before returning
//! and tallied in [`EngineStats::pad_rows`] — and are *not* billed as
//! useful sub-word multiplies (a conv layer's useful work is the real
//! images' patch rows, `m · out_pixels`).

use std::sync::Arc;

use crate::bits::fixed::sign_extend;
use crate::bits::format::{format_index, SimdFormat, FORMATS};
use crate::bits::pack::pack_stream_append;
use crate::bits::swar::{swar_add, swar_relu};
use crate::nn::conv::{ConvShape, LayerOp};
use crate::pipeline::stage1::Stage1;
use crate::pipeline::stage2::{repack_hop_into, widen_double};

use super::model::CompiledModel;

/// Cycle/energy tallies of one engine run. Aggregate counters are kept
/// for quick reads; the `*_by_fmt` arrays (indexed parallel to
/// [`FORMATS`]) split the same work by the format it ran at, which is
/// what exact per-format energy billing needs once layers differ in
/// width ([`super::cost::CostTable::batch_energy_pj`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EngineStats {
    pub s1_cycles: u64,
    /// Add/sub cycles among `s1_cycles` (CSD nonzero digits) — the
    /// datapath work the certificate prices separately from shifts.
    pub s1_adds: u64,
    pub s2_passes: u64,
    pub acc_adds: u64,
    /// Useful sub-word multiplies: real batch rows only — zero-pad
    /// lanes are excluded, consistent with `repack_cycles_exact`'s
    /// padding-exempt accounting. A conv layer's real rows are the real
    /// images' im2col patch rows (`m · out_pixels`).
    pub subword_mults: u64,
    /// Zero rows appended to fill the last packed word of the batch.
    pub pad_rows: u64,
    /// Plan × word executions elided because the operand word was all
    /// zero (activation zero-skipping, DESIGN.md §18). One unit = one
    /// (plan, packed word) pair whose Stage-1 execution never ran.
    pub skipped_plans: u64,
    /// Stage-1 cycles the skipped executions *would* have cost — what
    /// closes the conservation law `cert.s1_cycles == s1_cycles +
    /// skipped_cycles` the billing auditor checks.
    pub skipped_cycles: u64,
    /// Add/sub cycles among `skipped_cycles`.
    pub skipped_adds: u64,
    /// Stage-1 multiply cycles split by the format they ran at.
    pub s1_cycles_by_fmt: [u64; FORMATS.len()],
    /// Stage-1 add/sub cycles split by the format they ran at.
    pub s1_adds_by_fmt: [u64; FORMATS.len()],
    /// Stage-2 crossbar passes split by the format they *produced*.
    pub s2_passes_by_fmt: [u64; FORMATS.len()],
    /// Skipped Stage-1 cycles split by the format they would have run at.
    pub skipped_cycles_by_fmt: [u64; FORMATS.len()],
    /// Skipped add/sub cycles split by format.
    pub skipped_adds_by_fmt: [u64; FORMATS.len()],
}

impl EngineStats {
    #[inline]
    fn note_s1(&mut self, fmt: SimdFormat, cycles: u64, adds: u64) {
        self.s1_cycles += cycles;
        self.s1_cycles_by_fmt[format_index(fmt.bits)] += cycles;
        self.s1_adds += adds;
        self.s1_adds_by_fmt[format_index(fmt.bits)] += adds;
    }

    #[inline]
    fn note_s2(&mut self, produced: SimdFormat, passes: u64) {
        self.s2_passes += passes;
        self.s2_passes_by_fmt[format_index(produced.bits)] += passes;
    }

    /// Record `words` zero-skipped executions of a plan costing
    /// `plan_cycles`/`plan_adds` per word at format `fmt`.
    #[inline]
    fn note_skip(&mut self, fmt: SimdFormat, plan_cycles: u64, plan_adds: u64, words: u64) {
        let fi = format_index(fmt.bits);
        self.skipped_plans += words;
        self.skipped_cycles += plan_cycles * words;
        self.skipped_cycles_by_fmt[fi] += plan_cycles * words;
        self.skipped_adds += plan_adds * words;
        self.skipped_adds_by_fmt[fi] += plan_adds * words;
    }

    /// Observed zero-skip savings share: the fraction of the dense
    /// Stage-1 cycle bill that was elided (`skipped / (executed +
    /// skipped)`, cycle-weighted — the honest derivable sparsity
    /// metric). `None` when the run billed no Stage-1 work at all.
    pub fn skip_fraction(&self) -> Option<f64> {
        let total = self.skipped_cycles + self.s1_cycles;
        if total == 0 {
            return None;
        }
        Some(self.skipped_cycles as f64 / total as f64)
    }
}

/// Reusable per-worker execution state: every buffer the packed forward
/// pass needs, owned by the caller and warmed by the first batch. A PE
/// worker keeps one across its whole lifetime (`server.rs`), so
/// steady-state serving allocates nothing (DESIGN.md §11).
///
/// Lifecycle: all buffers are `clear()`ed and refilled per use — their
/// capacity persists; nothing is freed between batches. A scratch is
/// not tied to a model: reusing one across models is safe, it merely
/// re-warms.
#[derive(Debug)]
pub struct EngineScratch {
    /// The Stage-1 datapath (its cycle counters are drained into
    /// `EngineStats` after every plan × word-stream unit).
    s1: Stage1,
    /// Packed activation columns of the current layer: `k` columns ×
    /// `words_per_col`, column-major, at the layer's activation format.
    h: Vec<u64>,
    /// Next layer's activation columns (boundary output staging).
    h_next: Vec<u64>,
    /// Weight-stationary accumulator block: `n` columns × `acc_words`
    /// at the layer's accumulator format.
    acc: Vec<u64>,
    /// Product words of one (column, weight) pair (generic widen path).
    prod: Vec<u64>,
    /// Widened/converted stream staging (generic path + boundary hops).
    wide: Vec<u64>,
    /// Intermediate hop staging for multi-hop boundary chains.
    stage: Vec<u64>,
    /// Scalar staging for column gathers: the first layer's batch
    /// columns and every im2col patch / flatten column (DESIGN.md §12).
    col: Vec<i64>,
    /// Scalar feature-map staging of a conv-adjacent layer boundary:
    /// `mp` images × flattened feature length, image-major, features in
    /// `[channel][y][x]` order — what the next layer's im2col or
    /// flatten gather reads (DESIGN.md §12).
    fmap: Vec<i64>,
    /// Warmed output rows parked by a smaller batch, re-adopted by a
    /// later larger one — shrink-then-grow serving stays allocation-free.
    spare_rows: Vec<Vec<i64>>,
}

impl EngineScratch {
    pub fn new() -> EngineScratch {
        EngineScratch {
            s1: Stage1::new(SimdFormat::new(8)),
            h: Vec::new(),
            h_next: Vec::new(),
            acc: Vec::new(),
            prod: Vec::new(),
            wide: Vec::new(),
            stage: Vec::new(),
            col: Vec::new(),
            fmap: Vec::new(),
            spare_rows: Vec::new(),
        }
    }
}

impl Default for EngineScratch {
    fn default() -> Self {
        EngineScratch::new()
    }
}

/// Gather one im2col patch column (`k` = patch index, fixed) for every
/// output pixel of every image into `col` — patch rows ordered
/// `(image, oy, ox)` image-major. `src(b, idx)` reads flattened feature
/// `idx` (`[ci][y][x]` order) of image `b`; padding taps read zero.
/// Writes straight into the caller's scalar column buffer: no per-patch
/// allocation ever happens (DESIGN.md §12).
fn gather_conv_column<F: Fn(usize, usize) -> i64>(
    shape: &ConvShape,
    k: usize,
    images: usize,
    src: F,
    col: &mut Vec<i64>,
) {
    col.clear();
    let (oh, ow) = (shape.out_h(), shape.out_w());
    for b in 0..images {
        for oy in 0..oh {
            for ox in 0..ow {
                // One shared encoding of the patch-index decomposition:
                // the engine gathers through the exact `src_index` the
                // scalar oracle reads through, so the two can never
                // disagree on patch order or padding semantics.
                col.push(shape.src_index(k, oy, ox).map_or(0, |i| src(b, i)));
            }
        }
    }
}

/// Which execution backend runs the flat core (DESIGN.md §16). The
/// `Wide` variant exists only when the host-vector backend is compiled
/// in **and** the lane sanitizer is not: `lanecheck`'s per-word hooks
/// live in the scalar SWAR primitives, so sanitizer builds are pinned
/// to the scalar path by construction — `--features lanecheck,simd`
/// compiles, runs scalar, and records identically to plain `lanecheck`.
#[derive(Debug, Clone, Copy)]
enum Exec {
    Scalar,
    #[cfg(all(feature = "simd", not(feature = "lanecheck")))]
    Wide(crate::bits::swarx::Kernel),
}

/// One boundary/widen crossbar hop on the selected backend. Both forms
/// produce identical bits; only the gather's inner-loop shape differs.
#[inline]
fn hop_into(
    exec: Exec,
    src: &[u64],
    from: SimdFormat,
    to: SimdFormat,
    count: usize,
    dst: &mut Vec<u64>,
) {
    match exec {
        Exec::Scalar => repack_hop_into(src, from, to, count, dst),
        #[cfg(all(feature = "simd", not(feature = "lanecheck")))]
        Exec::Wide(_) => {
            crate::pipeline::stage2::repack_hop_into_wide(src, from, to, count, dst)
        }
    }
}

/// A packed-execution engine bound to one PE, sharing one compiled model.
pub struct PackedEngine {
    model: Arc<CompiledModel>,
    /// Activation zero-skipping (DESIGN.md §18): when on (the default),
    /// a plan's Stage-1 execution is elided for every packed operand
    /// word that is all zero — bit-exact (0 · w = 0; the elided
    /// accumulate is the identity), with the saved work tallied in
    /// [`EngineStats::skipped_cycles`]. Off restores the dense engine
    /// (the A/B baseline the benches difference against).
    zero_skip: bool,
}

impl PackedEngine {
    /// Bind a PE to a shared compiled model. Cheap: no plan compilation
    /// and no weight copies happen here. Activation zero-skipping is on
    /// by default ([`with_zero_skip`]).
    ///
    /// [`with_zero_skip`]: PackedEngine::with_zero_skip
    pub fn new(model: Arc<CompiledModel>) -> Self {
        PackedEngine { model, zero_skip: true }
    }

    /// Builder: enable/disable activation zero-skipping. Disabling it
    /// restores the dense engine — every plan executes over every word,
    /// `skipped_*` counters stay zero, and measured stats equal the
    /// cost certificate exactly (the no-skip A/B baseline).
    pub fn with_zero_skip(mut self, on: bool) -> Self {
        self.zero_skip = on;
        self
    }

    pub fn zero_skip(&self) -> bool {
        self.zero_skip
    }

    pub fn model(&self) -> &CompiledModel {
        &self.model
    }

    /// Forward a batch (rows of `Q1.(in_bits-1)` raws at the reference
    /// variant's first-layer activation format; for a conv-first model
    /// each row is the flattened `[cin][h][w]` image) through all
    /// layers using packed arithmetic; returns final accumulators
    /// (`Q1.(acc_bits-1)` at the last layer's accumulator format) per
    /// row, plus tallies.
    ///
    /// Convenience wrapper over [`forward_batch_into`] at the reference
    /// variant with one-shot buffers — tests, evals and examples. The
    /// serving loop threads a long-lived [`EngineScratch`] instead.
    ///
    /// [`forward_batch_into`]: PackedEngine::forward_batch_into
    pub fn forward_batch(&self, batch: &[Vec<i64>]) -> (Vec<Vec<i64>>, EngineStats) {
        self.forward_batch_variant(batch, 0)
    }

    /// As [`forward_batch`], executing precision variant `variant` —
    /// rows must already be quantized to that variant's first-layer
    /// format ([`Variant::quantize_row`]).
    ///
    /// [`forward_batch`]: PackedEngine::forward_batch
    /// [`Variant::quantize_row`]: super::model::Variant::quantize_row
    pub fn forward_batch_variant(
        &self,
        batch: &[Vec<i64>],
        variant: usize,
    ) -> (Vec<Vec<i64>>, EngineStats) {
        let mut scratch = EngineScratch::new();
        let mut out = Vec::with_capacity(batch.len());
        let stats = self.forward_batch_into(batch, variant, &mut scratch, &mut out);
        (out, stats)
    }

    /// The allocation-free execution core: as [`forward_batch_variant`],
    /// but every intermediate lives in `scratch` and the per-row logits
    /// are written into `out` (rows reused in place). After a batch has
    /// warmed the buffers at each served variant's shapes, a
    /// steady-state call performs **zero** heap allocations — variant
    /// switches included (enforced by the counting-allocator test, for
    /// conv schedules too). `variant` selects which precision variant of
    /// the shared model executes; lane occupancy, padding quantum,
    /// boundary chains and all per-format billing follow that variant's
    /// schedule, while the CSD plans are the one shared set
    /// (DESIGN.md §13).
    ///
    /// [`forward_batch_variant`]: PackedEngine::forward_batch_variant
    pub fn forward_batch_into(
        &self,
        batch: &[Vec<i64>],
        variant: usize,
        scratch: &mut EngineScratch,
        out: &mut Vec<Vec<i64>>,
    ) -> EngineStats {
        // Backend resolution is compile-time + one cached feature probe:
        // the host-vector backend when compiled in (and the lane
        // sanitizer out — its hooks are scalar-word-at-a-time), the
        // scalar core otherwise.
        #[cfg(all(feature = "simd", not(feature = "lanecheck")))]
        let exec = Exec::Wide(crate::bits::swarx::kernel());
        #[cfg(not(all(feature = "simd", not(feature = "lanecheck"))))]
        let exec = Exec::Scalar;
        self.forward_batch_exec(batch, variant, scratch, out, exec)
    }

    /// As [`forward_batch_into`], forcing the scalar core even when the
    /// `simd` backend is compiled in — the in-process bit-exact baseline
    /// the property tests and benches difference the vector path
    /// against (DESIGN.md §16).
    ///
    /// [`forward_batch_into`]: PackedEngine::forward_batch_into
    #[cfg(feature = "simd")]
    pub fn forward_batch_into_scalar(
        &self,
        batch: &[Vec<i64>],
        variant: usize,
        scratch: &mut EngineScratch,
        out: &mut Vec<Vec<i64>>,
    ) -> EngineStats {
        self.forward_batch_exec(batch, variant, scratch, out, Exec::Scalar)
    }

    fn forward_batch_exec(
        &self,
        batch: &[Vec<i64>],
        variant: usize,
        scratch: &mut EngineScratch,
        out: &mut Vec<Vec<i64>>,
        exec: Exec,
    ) -> EngineStats {
        let model = &*self.model;
        let var = model.variant(variant);
        let arena = model.flat();
        // Approximate variants execute their truncated plan bank; exact
        // variants (and every pre-§18 model) run bank 0.
        let bank = var.plan_bank();
        let zero_skip = self.zero_skip;
        let m = batch.len();
        assert!(m > 0, "empty batch");
        // Pad the batch dimension to the model's batch quantum: packed
        // words run full at every layer's format and no layer's
        // accumulator stream has a partial final word — every
        // words-per-column count below is exact, never a ceiling.
        // A conv layer's packed row count `mp · out_pixels` inherits
        // every divisibility from `mp`. The quantum is the *executed
        // variant's* — padding follows whichever schedule runs.
        let quantum = var.batch_quantum();
        let mp = m.div_ceil(quantum) * quantum;
        let mut stats = EngineStats {
            pad_rows: (mp - m) as u64,
            ..EngineStats::default()
        };
        let layers = model.layers();
        assert_eq!(batch[0].len(), layers[0].in_len(), "layer 0 input width");

        let EngineScratch {
            s1,
            h,
            h_next,
            acc,
            prod,
            wide,
            stage,
            col,
            fmap,
            spare_rows,
        } = scratch;

        // Whether `h` already holds this layer's packed activation
        // columns (dense→dense boundaries keep the stream packed;
        // conv-adjacent boundaries stage scalars in `fmap` instead).
        let mut h_is_packed = false;

        for (li, layer) in layers.iter().enumerate() {
            let prec = var.precision(li);
            let (in_fmt, acc_fmt) = (prec.in_fmt(), prec.acc_fmt());
            let w = layer.weights();
            // Packed rows this layer streams: every image is one row of
            // a dense layer and `out_pixels` im2col patch rows of a
            // conv layer (DESIGN.md §12).
            let prows = layer.patch_rows();
            let rows = mp * prows;
            let cur_words = rows / in_fmt.lanes() as usize;

            // ---- Gather stage: pack this layer's activation columns.
            // Dense→dense boundaries leave them packed already; the
            // first layer and every conv-adjacent layer gather scalars
            // (batch rows, im2col patches, or the flatten view of
            // `fmap`) through `col` into the canonical range-checked
            // lane pack.
            if !h_is_packed {
                h.clear();
                match layer {
                    LayerOp::Dense(_) => {
                        for k in 0..w.k {
                            col.clear();
                            if li == 0 {
                                for row in batch {
                                    col.push(row[k]);
                                }
                                col.resize(mp, 0);
                            } else {
                                // Flatten gather: feature `k` of every
                                // staged image.
                                for b in 0..mp {
                                    col.push(fmap[b * w.k + k]);
                                }
                            }
                            pack_stream_append(col, in_fmt, h);
                        }
                    }
                    LayerOp::Conv(c) => {
                        for k in 0..w.k {
                            if li == 0 {
                                gather_conv_column(
                                    &c.shape,
                                    k,
                                    mp,
                                    |b, idx| if b < m { batch[b][idx] } else { 0 },
                                    col,
                                );
                            } else {
                                let feat = c.shape.in_len();
                                gather_conv_column(
                                    &c.shape,
                                    k,
                                    mp,
                                    |b, idx| fmap[b * feat + idx],
                                    col,
                                );
                            }
                            pack_stream_append(col, in_fmt, h);
                        }
                    }
                }
            }
            assert_eq!(h.len(), w.k * cur_words, "layer {li} input width");

            s1.set_fmt(in_fmt);
            s1.reset_counters();
            let acc_words = rows * prec.acc_bits as usize / 48;
            // Fast path: the accumulate format is exactly double the
            // input format — use the SWAR widen instead of the generic
            // stream repack (DESIGN.md §9).
            let doubling = prec.acc_bits == 2 * prec.in_bits;
            // Weight-stationary block: accumulators for *all* n output
            // columns of this layer live in scratch at once, so each
            // flat plan is fetched exactly once and streamed over the
            // whole packed column.
            acc.clear();
            acc.resize(w.n * acc_words, 0);
            for n in 0..w.n {
                let acc_col = &mut acc[n * acc_words..(n + 1) * acc_words];
                // The k plan headers feeding column n are adjacent.
                for (k, hdr) in arena.column_bank(bank, li, n).iter().enumerate() {
                    if hdr.is_zero() {
                        continue; // zero weight: zero-skipped entirely
                    }
                    let ops = arena.ops(*hdr);
                    let x_col = &h[k * cur_words..(k + 1) * cur_words];
                    // Activation zero-skipping (DESIGN.md §18): a packed
                    // word of all-zero activations multiplies to zero
                    // under any plan, so its Stage-1 execution is elided
                    // and the word tallied here. The accumulate/widen
                    // billing below stays value-independent (a skipped
                    // word's accumulate is the identity add — the
                    // datapath still spends that cycle; eliding the host
                    // `swar_add` is a pure software optimization), so
                    // only the `s1_*` counters shrink versus the dense
                    // certificate — by exactly `hdr.cycles/adds` per
                    // skipped word, the conservation law `billaudit`
                    // checks.
                    let mut skipped_words = 0u64;
                    if doubling {
                        // Fused multiply → widen → accumulate per word:
                        // one accumulate add and one widen pass per
                        // produced accumulator word (always both, once
                        // the batch is padded to the batch quantum).
                        // The wide backend runs whole tiles through
                        // `run_flat_tile` first; the scalar loop covers
                        // the sub-tile tail from `start` — same words,
                        // same counter increments, either way.
                        let start = match exec {
                            Exec::Scalar => 0,
                            #[cfg(all(feature = "simd", not(feature = "lanecheck")))]
                            Exec::Wide(kern) => {
                                use crate::bits::swarx::TILE;
                                for (ti, c) in x_col.chunks_exact(TILE).enumerate() {
                                    let tile = [c[0], c[1], c[2], c[3]];
                                    // A tile skips when all TILE words
                                    // are zero; a mixed tile falls back
                                    // per-word so its zero words still
                                    // bill no Stage-1 cycles — the
                                    // counters match the scalar core
                                    // word for word either way.
                                    let p = if zero_skip && tile == [0; TILE] {
                                        skipped_words += TILE as u64;
                                        [0u64; TILE]
                                    } else if zero_skip && tile.contains(&0) {
                                        let mut p = [0u64; TILE];
                                        for (j, &word) in tile.iter().enumerate() {
                                            if word == 0 {
                                                skipped_words += 1;
                                            } else {
                                                p[j] = s1.run_flat(word, ops);
                                            }
                                        }
                                        p
                                    } else {
                                        s1.run_flat_tile(kern, tile, ops)
                                    };
                                    for (j, &pw) in p.iter().enumerate() {
                                        let wi = ti * TILE + j;
                                        if !(zero_skip && tile[j] == 0) {
                                            let (lo, hi) = widen_double(pw, in_fmt);
                                            acc_col[2 * wi] =
                                                swar_add(acc_col[2 * wi], lo, acc_fmt);
                                            if 2 * wi + 1 < acc_words {
                                                acc_col[2 * wi + 1] = swar_add(
                                                    acc_col[2 * wi + 1],
                                                    hi,
                                                    acc_fmt,
                                                );
                                            }
                                        }
                                        stats.acc_adds += 1;
                                        stats.note_s2(acc_fmt, 1);
                                        if 2 * wi + 1 < acc_words {
                                            stats.acc_adds += 1;
                                            stats.note_s2(acc_fmt, 1);
                                        }
                                    }
                                }
                                x_col.len() - x_col.len() % TILE
                            }
                        };
                        for (wi, &word) in x_col.iter().enumerate().skip(start) {
                            if zero_skip && word == 0 {
                                skipped_words += 1;
                            } else {
                                let p = s1.run_flat(word, ops);
                                let (lo, hi) = widen_double(p, in_fmt);
                                acc_col[2 * wi] = swar_add(acc_col[2 * wi], lo, acc_fmt);
                                if 2 * wi + 1 < acc_words {
                                    acc_col[2 * wi + 1] =
                                        swar_add(acc_col[2 * wi + 1], hi, acc_fmt);
                                }
                            }
                            stats.acc_adds += 1;
                            stats.note_s2(acc_fmt, 1);
                            if 2 * wi + 1 < acc_words {
                                stats.acc_adds += 1;
                                stats.note_s2(acc_fmt, 1);
                            }
                        }
                    } else if in_fmt == acc_fmt {
                        // Equal widths: the product words accumulate
                        // as-is — no conversion happens, none is billed.
                        let start = match exec {
                            Exec::Scalar => 0,
                            #[cfg(all(feature = "simd", not(feature = "lanecheck")))]
                            Exec::Wide(kern) => {
                                use crate::bits::swarx::TILE;
                                for (ti, c) in x_col.chunks_exact(TILE).enumerate() {
                                    let tile = [c[0], c[1], c[2], c[3]];
                                    let p = if zero_skip && tile == [0; TILE] {
                                        skipped_words += TILE as u64;
                                        [0u64; TILE]
                                    } else if zero_skip && tile.contains(&0) {
                                        let mut p = [0u64; TILE];
                                        for (j, &word) in tile.iter().enumerate() {
                                            if word == 0 {
                                                skipped_words += 1;
                                            } else {
                                                p[j] = s1.run_flat(word, ops);
                                            }
                                        }
                                        p
                                    } else {
                                        s1.run_flat_tile(kern, tile, ops)
                                    };
                                    for (j, &pw) in p.iter().enumerate() {
                                        let wi = ti * TILE + j;
                                        if !(zero_skip && tile[j] == 0) {
                                            acc_col[wi] =
                                                swar_add(acc_col[wi], pw, acc_fmt);
                                        }
                                        stats.acc_adds += 1;
                                    }
                                }
                                x_col.len() - x_col.len() % TILE
                            }
                        };
                        for (wi, &word) in x_col.iter().enumerate().skip(start) {
                            if zero_skip && word == 0 {
                                skipped_words += 1;
                            } else {
                                let p = s1.run_flat(word, ops);
                                acc_col[wi] = swar_add(acc_col[wi], p, acc_fmt);
                            }
                            stats.acc_adds += 1;
                        }
                    } else {
                        // Generic widening (`acc ≥ in` always, so the
                        // hop is direct): products → one word-level hop
                        // → accumulate. Stage-2 passes are charged for
                        // the output words actually produced — with the
                        // batch padded to the quantum, `acc_words` ==
                        // `repack_cycles_exact(rows, in_fmt, acc_fmt)`.
                        prod.clear();
                        let start = match exec {
                            Exec::Scalar => 0,
                            #[cfg(all(feature = "simd", not(feature = "lanecheck")))]
                            Exec::Wide(kern) => {
                                use crate::bits::swarx::TILE;
                                for c in x_col.chunks_exact(TILE) {
                                    let tile = [c[0], c[1], c[2], c[3]];
                                    let p = if zero_skip && tile == [0; TILE] {
                                        skipped_words += TILE as u64;
                                        [0u64; TILE]
                                    } else if zero_skip && tile.contains(&0) {
                                        let mut p = [0u64; TILE];
                                        for (j, &word) in tile.iter().enumerate() {
                                            if word == 0 {
                                                skipped_words += 1;
                                            } else {
                                                p[j] = s1.run_flat(word, ops);
                                            }
                                        }
                                        p
                                    } else {
                                        s1.run_flat_tile(kern, tile, ops)
                                    };
                                    prod.extend_from_slice(&p);
                                }
                                x_col.len() - x_col.len() % TILE
                            }
                        };
                        for &word in &x_col[start..] {
                            if zero_skip && word == 0 {
                                // The skipped word's product is zero;
                                // the hop and accumulate below still
                                // stream it (and are billed) unchanged.
                                skipped_words += 1;
                                prod.push(0);
                            } else {
                                prod.push(s1.run_flat(word, ops));
                            }
                        }
                        stats.note_s2(acc_fmt, acc_words as u64);
                        hop_into(exec, prod, in_fmt, acc_fmt, rows, wide);
                        for (dst, &p) in acc_col.iter_mut().zip(wide.iter()) {
                            *dst = swar_add(*dst, p, acc_fmt);
                            stats.acc_adds += 1;
                        }
                    }
                    // Stage-1 billing is the datapath's own cycle count
                    // (one source of truth — never `plan.cycles()`
                    // on the side); zero-skipped words billed nothing
                    // there and are tallied as foregone work instead.
                    let (cycles, adds) = s1.take_counters();
                    debug_assert_eq!(
                        cycles,
                        hdr.cycles as u64 * (cur_words as u64 - skipped_words)
                    );
                    debug_assert_eq!(
                        adds,
                        hdr.adds as u64 * (cur_words as u64 - skipped_words)
                    );
                    stats.note_s1(in_fmt, cycles, adds);
                    if skipped_words > 0 {
                        stats.note_skip(
                            in_fmt,
                            hdr.cycles as u64,
                            hdr.adds as u64,
                            skipped_words,
                        );
                    }
                    // Only the m real rows (for conv: the real images'
                    // patch rows) are useful multiplies; the zero-pad
                    // lanes of the batch tail are not.
                    stats.subword_mults += (m * prows) as u64;
                }
            }
            if li + 1 < layers.len() {
                // Layer boundary, fully word-level: ReLU in one pass
                // over each column's accumulator stream, then each
                // precompiled crossbar hop over the whole packed stream
                // — the run-time sub-word bitwidth switch of Section
                // III-C with no unpack → per-value-convert → repack
                // round trip. An empty chain is a Stage-2 bypass: no
                // crossbar traversal happens and none is billed.
                //
                // Dense→dense boundaries hand the converted stream
                // straight to the next layer still packed. A boundary
                // touching a conv layer additionally scatters it into
                // the scalar feature-map staging, because the next
                // gather reads features at arbitrary offsets (im2col
                // patches overlap; the flatten view interleaves
                // channels) — the conversion itself, and its billing,
                // are identical either way (DESIGN.md §12).
                let next = &layers[li + 1];
                let chain = var.boundary_chain(li);
                let packed_boundary = !layer.is_conv() && !next.is_conv();
                let next_in_fmt = var.precision(li + 1).in_fmt();
                let feat = layer.out_len();
                if packed_boundary {
                    h_next.clear();
                } else {
                    fmap.resize(mp * feat, 0);
                }
                for n in 0..w.n {
                    let span = n * acc_words..(n + 1) * acc_words;
                    match exec {
                        Exec::Scalar => {
                            for word in acc[span.clone()].iter_mut() {
                                *word = swar_relu(*word, acc_fmt);
                            }
                        }
                        #[cfg(all(feature = "simd", not(feature = "lanecheck")))]
                        Exec::Wide(kern) => {
                            crate::bits::swarx::relu_slice(
                                kern,
                                &mut acc[span.clone()],
                                acc_fmt,
                            );
                        }
                    }
                    let acc_col = &acc[span];
                    let converted: &[u64] = if chain.is_empty() {
                        acc_col
                    } else {
                        hop_into(exec, acc_col, chain[0].0, chain[0].1, rows, wide);
                        for &(f, t) in &chain[1..] {
                            std::mem::swap(wide, stage);
                            hop_into(exec, stage, f, t, rows, wide);
                        }
                        wide.as_slice()
                    };
                    if packed_boundary {
                        h_next.extend_from_slice(converted);
                    } else {
                        // Scatter the converted column into the scalar
                        // feature map: patch row `r` of image `r/prows`
                        // is feature `n·prows + r%prows` (`[channel]
                        // [y][x]` order — for a dense producer `prows`
                        // is 1 and this is the plain transpose).
                        let lanes = next_in_fmt.lanes() as usize;
                        let mask = (1u64 << next_in_fmt.bits) - 1;
                        for r in 0..rows {
                            let v = sign_extend(
                                (converted[r / lanes]
                                    >> ((r % lanes) as u32 * next_in_fmt.bits))
                                    & mask,
                                next_in_fmt.bits,
                            );
                            fmap[(r / prows) * feat + n * prows + (r % prows)] = v;
                        }
                    }
                }
                // One crossbar cycle per output word each hop produces,
                // per output column — billed to the format produced.
                for &(_, t) in chain {
                    let passes = (rows * t.bits as usize).div_ceil(48) as u64;
                    stats.note_s2(t, passes * w.n as u64);
                }
                if packed_boundary {
                    std::mem::swap(h, h_next);
                }
                h_is_packed = packed_boundary;
            } else {
                // Untranspose the accumulator block into row-major
                // logits, dropping the pad rows; a conv final layer
                // flattens back to `[cout][oy][ox]` feature order.
                // `out`'s rows are reused in place; a smaller batch
                // parks its surplus warmed rows in the scratch so a
                // later larger batch re-adopts them instead of
                // allocating.
                let acc_lanes = acc_fmt.lanes() as usize;
                let mask = (1u64 << acc_fmt.bits) - 1;
                while out.len() > m {
                    spare_rows.push(out.pop().expect("len checked"));
                }
                while out.len() < m {
                    out.push(spare_rows.pop().unwrap_or_default());
                }
                for (b, row) in out.iter_mut().enumerate() {
                    row.clear();
                    for n in 0..w.n {
                        for within in 0..prows {
                            let r = b * prows + within;
                            let word = acc[n * acc_words + r / acc_lanes];
                            row.push(sign_extend(
                                (word >> ((r % acc_lanes) as u32 * acc_fmt.bits)) & mask,
                                acc_fmt.bits,
                            ));
                        }
                    }
                }
                // Grow the spare pool's spine now, while still in the
                // call that grew `out` (a warming event by definition),
                // so a later smaller batch parks its surplus rows
                // without touching the allocator.
                spare_rows.reserve(out.len());
                // The differential billing auditor (DESIGN.md §15):
                // every executed batch's stats are checked against the
                // static certificate at this batch's row count.
                #[cfg(feature = "billaudit")]
                crate::analysis::cost::audit::check_batch(
                    model.cost_certificate(variant),
                    &stats,
                    m,
                );
                return stats;
            }
        }
        unreachable!("CompiledModel::compile rejects empty layer stacks")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::exec::{mlp_forward_row, mlp_forward_row_mixed, stack_forward_row};
    use crate::nn::weights::{uniform_schedule, LayerPrecision, QuantLayer};
    use crate::testutil::{
        engine_for, engine_uniform, random_batch, random_conv_for_shape,
        random_dense_stack_uniform,
    };
    use crate::workload::synth::XorShift64;

    fn random_layers(rng: &mut XorShift64) -> Vec<QuantLayer> {
        random_dense_stack_uniform(rng, &[10, 6, 4], 8)
    }

    #[test]
    fn packed_engine_matches_scalar_reference() {
        let mut rng = XorShift64::new(0xE8E8);
        let layers = random_layers(&mut rng);
        let engine = engine_uniform(layers.clone(), 8, 16);
        for batch_size in [1usize, 3, 6, 16, 17] {
            let batch: Vec<Vec<i64>> = (0..batch_size)
                .map(|_| (0..10).map(|_| rng.q_raw(8)).collect())
                .collect();
            let (got, stats) = engine.forward_batch(&batch);
            assert_eq!(got.len(), batch_size, "pad rows must be dropped");
            for (b, row) in batch.iter().enumerate() {
                let want = mlp_forward_row(row, &layers, 8, 16);
                assert_eq!(got[b], want, "batch row {b} (size {batch_size})");
            }
            assert!(stats.s1_cycles > 0);
            assert!(stats.s2_passes > 0);
            assert_eq!(
                stats.pad_rows as usize,
                batch_size.div_ceil(6) * 6 - batch_size
            );
        }
    }

    #[test]
    fn reused_scratch_is_bit_identical_to_fresh_buffers() {
        // One scratch threaded across differently-shaped batches and
        // models must never leak state between runs.
        let mut rng = XorShift64::new(0xE8EA);
        let layers = random_layers(&mut rng);
        let sched_a = vec![LayerPrecision::new(8, 16), LayerPrecision::new(8, 16)];
        let sched_b = vec![LayerPrecision::new(4, 8), LayerPrecision::new(8, 16)];
        let mut scratch = EngineScratch::new();
        let mut out = Vec::new();
        for sched in [sched_a, sched_b] {
            let engine = engine_for(layers.clone(), sched.clone());
            for batch_size in [17usize, 3, 24, 1] {
                let batch: Vec<Vec<i64>> = (0..batch_size)
                    .map(|_| (0..10).map(|_| rng.q_raw(sched[0].in_bits)).collect())
                    .collect();
                let stats = engine.forward_batch_into(&batch, 0, &mut scratch, &mut out);
                let (fresh, fresh_stats) = engine.forward_batch(&batch);
                assert_eq!(out, fresh, "sched {sched:?} size {batch_size}");
                assert_eq!(stats.s1_cycles, fresh_stats.s1_cycles);
                assert_eq!(stats.s2_passes, fresh_stats.s2_passes);
                assert_eq!(stats.acc_adds, fresh_stats.acc_adds);
                assert_eq!(stats.subword_mults, fresh_stats.subword_mults);
            }
        }
    }

    #[test]
    fn mixed_precision_layers_match_scalar_oracle() {
        let mut rng = XorShift64::new(0xE8E9);
        let layers = random_layers(&mut rng);
        // Widening 4→8 activations (direct boundary) and a 16→4
        // boundary that needs the 2-hop chain.
        let schedules = [
            vec![LayerPrecision::new(4, 8), LayerPrecision::new(8, 16)],
            vec![LayerPrecision::new(8, 16), LayerPrecision::new(4, 8)],
        ];
        for sched in &schedules {
            let engine = engine_for(layers.clone(), sched.clone());
            for batch_size in [1usize, 5, 12, 25] {
                let batch: Vec<Vec<i64>> = (0..batch_size)
                    .map(|_| (0..10).map(|_| rng.q_raw(sched[0].in_bits)).collect())
                    .collect();
                let (got, stats) = engine.forward_batch(&batch);
                for (b, row) in batch.iter().enumerate() {
                    let want = mlp_forward_row_mixed(row, &layers, sched);
                    assert_eq!(got[b], want, "sched {:?} row {b}", sched);
                }
                // Stage-1 cycles landed in both layers' format buckets.
                for p in sched {
                    assert!(
                        stats.s1_cycles_by_fmt[format_index(p.in_bits)] > 0,
                        "no S1 cycles at {}b",
                        p.in_bits
                    );
                }
                assert_eq!(
                    stats.s1_cycles_by_fmt.iter().sum::<u64>(),
                    stats.s1_cycles
                );
                assert_eq!(
                    stats.s2_passes_by_fmt.iter().sum::<u64>(),
                    stats.s2_passes
                );
            }
        }
    }

    #[test]
    fn variant_switching_matches_each_variants_oracle_and_billing() {
        // One shared model carrying the standard trio: executing
        // variant v must be bit-identical to a single-variant model
        // compiled at v's schedule alone — same logits, same stats down
        // to the per-format buckets — with one scratch threaded across
        // interleaved variant switches (the serving shape).
        use crate::coordinator::model::VariantSpec;
        let mut rng = XorShift64::new(0xE8EB);
        let layers = random_layers(&mut rng);
        let specs = VariantSpec::standard_trio(layers.len());
        let ops: Vec<LayerOp> = layers.iter().cloned().map(LayerOp::Dense).collect();
        let set = CompiledModel::compile_variants(ops, specs.clone()).unwrap();
        let engine = PackedEngine::new(set);
        let mut scratch = EngineScratch::new();
        let mut out = Vec::new();
        for &(v, rows) in &[(0usize, 7usize), (2, 13), (1, 5), (0, 24), (2, 1)] {
            let sched = specs[v].schedule.clone();
            let batch = random_batch(&mut rng, rows, 10, sched[0].in_bits);
            let stats = engine.forward_batch_into(&batch, v, &mut scratch, &mut out);
            let single = engine_for(layers.clone(), sched.clone());
            let (want_out, want_stats) = single.forward_batch(&batch);
            assert_eq!(out, want_out, "variant {v} rows {rows}");
            assert_eq!(stats.s1_cycles, want_stats.s1_cycles, "variant {v}");
            assert_eq!(stats.s2_passes, want_stats.s2_passes, "variant {v}");
            assert_eq!(stats.acc_adds, want_stats.acc_adds, "variant {v}");
            assert_eq!(stats.subword_mults, want_stats.subword_mults, "variant {v}");
            assert_eq!(stats.pad_rows, want_stats.pad_rows, "variant {v}");
            assert_eq!(stats.s1_cycles_by_fmt, want_stats.s1_cycles_by_fmt);
            assert_eq!(stats.s2_passes_by_fmt, want_stats.s2_passes_by_fmt);
            for (b, row) in batch.iter().enumerate() {
                let want = mlp_forward_row_mixed(row, &layers, &sched);
                assert_eq!(out[b], want, "variant {v} row {b}");
            }
        }
    }

    #[test]
    fn conv_stack_matches_scalar_oracle() {
        // conv 1x6x6 → 3ch 3x3 s1 p1 → conv 3ch → 2ch 3x3 s2 p1 →
        // dense 18 → 4, uniform 8→16: every boundary kind (conv→conv,
        // conv→dense) plus the im2col gather from the raw batch.
        let mut rng = XorShift64::new(0xC0DE1);
        let c1 = random_conv_for_shape(
            &mut rng,
            ConvShape { cin: 1, h: 6, w: 6, cout: 3, kh: 3, kw: 3, stride: 1, pad: 1 },
            8,
        );
        let c2 = random_conv_for_shape(
            &mut rng,
            ConvShape { cin: 3, h: 6, w: 6, cout: 2, kh: 3, kw: 3, stride: 2, pad: 1 },
            8,
        );
        let dense = QuantLayer::new(
            (0..18).map(|_| (0..4).map(|_| rng.q_raw(8)).collect()).collect(),
            8,
        );
        let ops = vec![LayerOp::Conv(c1), LayerOp::Conv(c2), LayerOp::Dense(dense)];
        let sched = uniform_schedule(8, 16, 3);
        let model = CompiledModel::compile_stack(ops.clone(), sched.clone()).unwrap();
        let engine = PackedEngine::new(model);
        for batch_size in [1usize, 4, 7] {
            let batch: Vec<Vec<i64>> = (0..batch_size)
                .map(|_| (0..36).map(|_| rng.q_raw(8)).collect())
                .collect();
            let (got, stats) = engine.forward_batch(&batch);
            assert_eq!(got.len(), batch_size);
            for (b, row) in batch.iter().enumerate() {
                let want = stack_forward_row(row, &ops, &sched);
                assert_eq!(got[b], want, "batch row {b} (size {batch_size})");
            }
            // Conv useful multiplies count the real images' patch rows
            // exactly: Σ over layers of m · patch_rows · nonzero weights.
            let want_mults: u64 = ops
                .iter()
                .map(|op| {
                    let nz = op
                        .weights()
                        .w_raw
                        .iter()
                        .flatten()
                        .filter(|&&v| v != 0)
                        .count();
                    (batch_size * op.patch_rows() * nz) as u64
                })
                .sum();
            assert_eq!(stats.subword_mults, want_mults);
        }
    }

    #[test]
    fn conv_final_layer_returns_flattened_feature_maps() {
        // dense 4 → 8 then conv 2x2x2 → 2ch 2x2 s1 p0 (out 2x1x1):
        // exercises dense→conv staging and the conv untranspose.
        let mut rng = XorShift64::new(0xC0DE2);
        let dense = QuantLayer::new(
            (0..4).map(|_| (0..8).map(|_| rng.q_raw(8)).collect()).collect(),
            8,
        );
        let conv = random_conv_for_shape(
            &mut rng,
            ConvShape { cin: 2, h: 2, w: 2, cout: 2, kh: 2, kw: 2, stride: 1, pad: 0 },
            8,
        );
        let ops = vec![LayerOp::Dense(dense), LayerOp::Conv(conv)];
        let sched = uniform_schedule(8, 16, 2);
        let model = CompiledModel::compile_stack(ops.clone(), sched.clone()).unwrap();
        let engine = PackedEngine::new(model);
        let batch: Vec<Vec<i64>> = (0..5)
            .map(|_| (0..4).map(|_| rng.q_raw(8)).collect())
            .collect();
        let (got, _) = engine.forward_batch(&batch);
        for (b, row) in batch.iter().enumerate() {
            let want = stack_forward_row(row, &ops, &sched);
            assert_eq!(got[b], want, "row {b}");
            assert_eq!(got[b].len(), 2, "flattened [cout][oh][ow] length");
        }
    }

    #[test]
    fn conv_mixed_precision_boundaries_match_oracle() {
        // 4-bit conv front end widening into an 8-bit dense head, and a
        // narrowing 16→4 conv→dense boundary (2-hop chain) — the
        // run-time bitwidth switch on conv streams.
        let mut rng = XorShift64::new(0xC0DE3);
        let shape =
            ConvShape { cin: 1, h: 4, w: 4, cout: 2, kh: 2, kw: 2, stride: 2, pad: 0 };
        for sched in [
            vec![LayerPrecision::new(4, 8), LayerPrecision::new(8, 16)],
            vec![LayerPrecision::new(8, 16), LayerPrecision::new(4, 8)],
        ] {
            let conv = random_conv_for_shape(&mut rng, shape, 4);
            let dense = QuantLayer::new(
                (0..8).map(|_| (0..3).map(|_| rng.q_raw(4)).collect()).collect(),
                4,
            );
            let ops = vec![LayerOp::Conv(conv), LayerOp::Dense(dense)];
            let model = CompiledModel::compile_stack(ops.clone(), sched.clone()).unwrap();
            let engine = PackedEngine::new(model);
            let batch: Vec<Vec<i64>> = (0..9)
                .map(|_| (0..16).map(|_| rng.q_raw(sched[0].in_bits)).collect())
                .collect();
            let (got, _) = engine.forward_batch(&batch);
            for (b, row) in batch.iter().enumerate() {
                let want = stack_forward_row(row, &ops, &sched);
                assert_eq!(got[b], want, "sched {sched:?} row {b}");
            }
        }
    }

    #[test]
    fn zero_weights_cost_nothing() {
        let layers = vec![QuantLayer::new(vec![vec![0, 64], vec![0, -32]], 8)];
        let engine = engine_uniform(layers, 8, 16);
        let batch = vec![vec![100i64, -50], vec![25, 77]];
        let (_, stats) = engine.forward_batch(&batch);
        // Column n=0 is all-zero weights: only n=1's two weights run.
        let plan_cycles: u64 = [64i64, -32]
            .iter()
            .map(|&w| crate::csd::schedule::schedule(w, 8).cycles() as u64)
            .sum();
        assert_eq!(stats.s1_cycles, plan_cycles); // one packed word per column
    }

    #[test]
    fn zero_activation_words_skip_stage1_and_stay_bit_exact() {
        // 1×1 layer, weight 77: a 12-row batch packs into 2 input words;
        // rows 6..12 are all zero, so the second word is zero and its
        // plan execution must be elided — same logits, half the Stage-1
        // bill, the other half tallied as skipped.
        let layers = vec![QuantLayer::new(vec![vec![77]], 8)];
        let plan_cycles = crate::csd::schedule::schedule(77, 8).cycles() as u64;
        let plan_adds = crate::csd::schedule::schedule(77, 8).adds() as u64;
        let batch: Vec<Vec<i64>> = (0..12)
            .map(|i| vec![if i < 6 { i as i64 * 9 - 20 } else { 0 }])
            .collect();
        let skip = engine_uniform(layers.clone(), 8, 16);
        let dense = PackedEngine::new(skip.model.clone()).with_zero_skip(false);
        assert!(skip.zero_skip() && !dense.zero_skip());
        let (got, stats) = skip.forward_batch(&batch);
        let (want, dense_stats) = dense.forward_batch(&batch);
        assert_eq!(got, want, "zero-skipping must be bit-exact");
        assert_eq!(stats.s1_cycles, plan_cycles);
        assert_eq!(stats.skipped_cycles, plan_cycles);
        assert_eq!(stats.skipped_adds, plan_adds);
        assert_eq!(stats.skipped_plans, 1);
        assert_eq!(stats.skip_fraction(), Some(0.5));
        // The dense baseline bills both words and skips nothing.
        assert_eq!(dense_stats.s1_cycles, 2 * plan_cycles);
        assert_eq!(dense_stats.skipped_cycles, 0);
        assert_eq!(dense_stats.skipped_plans, 0);
        // Conservation: executed + skipped == the dense bill, per bucket.
        assert_eq!(stats.s1_cycles + stats.skipped_cycles, dense_stats.s1_cycles);
        assert_eq!(stats.s1_adds + stats.skipped_adds, dense_stats.s1_adds);
        for fi in 0..FORMATS.len() {
            assert_eq!(
                stats.s1_cycles_by_fmt[fi] + stats.skipped_cycles_by_fmt[fi],
                dense_stats.s1_cycles_by_fmt[fi]
            );
        }
        // Value-independent counters are untouched by skipping.
        assert_eq!(stats.acc_adds, dense_stats.acc_adds);
        assert_eq!(stats.s2_passes, dense_stats.s2_passes);
        assert_eq!(stats.subword_mults, dense_stats.subword_mults);
    }

    #[test]
    fn pad_only_words_skip_downstream_layers() {
        // Mixed schedule [(4,8),(8,16)] has batch quantum 12: a 3-row
        // batch pads with 9 zero rows, so layer 1's second input word
        // (rows 6..12, all pad) is zero post-ReLU and must be skipped
        // even on a dense-values batch.
        let mut rng = XorShift64::new(0x5C1B);
        let layers = random_dense_stack_uniform(&mut rng, &[4, 3, 2], 4);
        let sched = vec![LayerPrecision::new(4, 8), LayerPrecision::new(8, 16)];
        let engine = engine_for(layers.clone(), sched.clone());
        let batch: Vec<Vec<i64>> = (0..3)
            .map(|_| (0..4).map(|_| rng.q_raw(4)).collect())
            .collect();
        let (got, stats) = engine.forward_batch(&batch);
        assert!(stats.skipped_plans > 0, "pad-only words must skip");
        for (b, row) in batch.iter().enumerate() {
            let want = mlp_forward_row_mixed(row, &layers, &sched);
            assert_eq!(got[b], want, "row {b}");
        }
    }

    #[test]
    fn truncated_variant_is_bit_exact_when_truncation_drops_nothing() {
        // Power-of-two weights encode to single-digit CSD, so
        // keep-digits(1) removes nothing: the approximate variant must
        // be bit-identical to the exact one on the same bank layout —
        // the "truncation removes nothing ⇒ bit-exact" property.
        use crate::coordinator::model::VariantSpec;
        use crate::csd::schedule::Truncation;
        let mut rng = XorShift64::new(0xAB1E);
        let pow2 = |rng: &mut XorShift64| -> i64 {
            let mag = 1i64 << (rng.next_u64() % 7);
            if rng.next_u64() % 2 == 0 { mag } else { -mag }
        };
        let layers: Vec<QuantLayer> = [(5usize, 4usize), (4, 3)]
            .iter()
            .map(|&(k, n)| {
                QuantLayer::new(
                    (0..k).map(|_| (0..n).map(|_| pow2(&mut rng)).collect()).collect(),
                    8,
                )
            })
            .collect();
        let ops: Vec<LayerOp> = layers.iter().cloned().map(LayerOp::Dense).collect();
        let sched = uniform_schedule(8, 16, 2);
        let specs = vec![
            VariantSpec::new("exact", sched.clone()),
            VariantSpec::new("d1", sched).with_truncation(Truncation::keep_digits(1)),
        ];
        let model = CompiledModel::compile_variants(ops, specs).unwrap();
        let engine = PackedEngine::new(model);
        let batch: Vec<Vec<i64>> = (0..7)
            .map(|_| (0..5).map(|_| rng.q_raw(8)).collect())
            .collect();
        let (exact, exact_stats) = engine.forward_batch_variant(&batch, 0);
        let (approx, approx_stats) = engine.forward_batch_variant(&batch, 1);
        assert_eq!(exact, approx, "single-digit weights truncate to themselves");
        assert_eq!(exact_stats, approx_stats, "identical plans, identical bill");
    }

    #[test]
    fn truncated_variant_bills_strictly_less_on_multi_digit_weights() {
        use crate::coordinator::model::VariantSpec;
        use crate::csd::schedule::Truncation;
        // Weights with dense CSD digit strings, so drop-least(2) removes
        // digits from some plan: the approximate variant's dense-
        // equivalent Stage-1 bill must shrink strictly.
        let layers = vec![QuantLayer::new(vec![vec![115, -77], vec![43, 127]], 8)];
        let ops: Vec<LayerOp> = layers.iter().cloned().map(LayerOp::Dense).collect();
        let sched = uniform_schedule(8, 16, 1);
        let specs = vec![
            VariantSpec::new("exact", sched.clone()),
            VariantSpec::new("t2", sched).with_truncation(Truncation::drop_least(2)),
        ];
        let model = CompiledModel::compile_variants(ops, specs).unwrap();
        let engine = PackedEngine::new(model);
        let batch: Vec<Vec<i64>> = (0..6)
            .map(|i| vec![i as i64 * 11 - 30, 19 - i as i64 * 7])
            .collect();
        let (_, exact) = engine.forward_batch_variant(&batch, 0);
        let (_, approx) = engine.forward_batch_variant(&batch, 1);
        assert!(
            approx.s1_cycles + approx.skipped_cycles
                < exact.s1_cycles + exact.skipped_cycles,
            "truncated bank must cost fewer Stage-1 cycles"
        );
    }

    #[test]
    fn stats_scale_with_batch_words() {
        let mut rng = XorShift64::new(0x57A7);
        let layers = random_layers(&mut rng);
        let engine = engine_uniform(layers, 8, 16);
        let mk_batch = |n: usize, rng: &mut XorShift64| -> Vec<Vec<i64>> {
            (0..n).map(|_| (0..10).map(|_| rng.q_raw(8)).collect()).collect()
        };
        let (_, s6) = engine.forward_batch(&mk_batch(6, &mut rng));
        let (_, s12) = engine.forward_batch(&mk_batch(12, &mut rng));
        // 6 rows = 1 packed word per column; 12 rows = 2 words. Dense
        // Stage-1 work (executed + zero-skipped — hidden-layer words can
        // go all-zero post-ReLU on random data) scales with the words.
        assert_eq!(
            s12.s1_cycles + s12.skipped_cycles,
            2 * (s6.s1_cycles + s6.skipped_cycles)
        );
        assert_eq!(s12.s2_passes, 2 * s6.s2_passes);
        assert_eq!(s12.acc_adds, 2 * s6.acc_adds);
    }

    #[test]
    fn stats_count_produced_acc_words_on_doubling_path() {
        // 1-layer 1×1 model, weight 64 (1-cycle plan): a 6-row batch
        // packs into one input word → two 16-bit accumulator words →
        // exactly 2 widen passes and 2 accumulate adds.
        let layers = vec![QuantLayer::new(vec![vec![64]], 8)];
        let engine = engine_uniform(layers, 8, 16);
        let batch: Vec<Vec<i64>> = (0..6).map(|i| vec![i as i64 * 10 - 25]).collect();
        let (_, stats) = engine.forward_batch(&batch);
        assert_eq!(stats.acc_adds, 2);
        assert_eq!(stats.s2_passes, 2);
        // A 3-row batch pads to the same single full word: same tallies.
        let (_, s3) = engine.forward_batch(&batch[..3].to_vec());
        assert_eq!(s3.acc_adds, 2);
        assert_eq!(s3.s2_passes, 2);
        assert_eq!(s3.pad_rows, 3);
    }

    #[test]
    fn subword_mults_bill_real_rows_not_pad_lanes() {
        // Regression (the pad-lane billing bug): a 3-row batch on a
        // 1×1 single-weight layer must report 3 useful multiplies per
        // word-weight, not the 6 lanes the padded word physically runs.
        let layers = vec![QuantLayer::new(vec![vec![64]], 8)];
        let engine = engine_uniform(layers, 8, 16);
        let batch: Vec<Vec<i64>> = (0..3).map(|i| vec![i as i64 * 7 - 3]).collect();
        let (_, stats) = engine.forward_batch(&batch);
        assert_eq!(stats.subword_mults, 3);
        assert_eq!(stats.pad_rows, 3);
        // A full 6-row word bills all 6 — padding-exempt, not lane-blind.
        let full: Vec<Vec<i64>> = (0..6).map(|i| vec![i as i64 * 7 - 3]).collect();
        let (_, s6) = engine.forward_batch(&full);
        assert_eq!(s6.subword_mults, 6);
        assert_eq!(s6.pad_rows, 0);
    }

    #[test]
    fn equal_width_accumulate_and_bypass_boundary_bill_no_passes() {
        // in == acc layer: products accumulate without any conversion,
        // so no crossbar pass may be billed.
        let layers = vec![QuantLayer::new(vec![vec![64]], 8)];
        let engine = engine_uniform(layers, 8, 8);
        let batch: Vec<Vec<i64>> = (0..6).map(|i| vec![i as i64 - 3]).collect();
        let (_, stats) = engine.forward_batch(&batch);
        assert_eq!(stats.s2_passes, 0);
        assert!(stats.acc_adds > 0);
        // Bypass boundary (acc == next layer's in): nothing billed
        // either — only the two layers' widen passes remain.
        let layers = vec![
            QuantLayer::new(vec![vec![64]], 8),
            QuantLayer::new(vec![vec![32]], 8),
        ];
        let sched = vec![LayerPrecision::new(4, 8), LayerPrecision::new(8, 16)];
        let engine = engine_for(layers, sched);
        let batch: Vec<Vec<i64>> = (0..12).map(|i| vec![(i % 8) as i64 - 4]).collect();
        let (_, stats) = engine.forward_batch(&batch);
        // 12 rows: layer 0 produces 2 acc words (@8b), layer 1 produces
        // 4 (@16b); the 8→8 boundary adds zero.
        assert_eq!(stats.s2_passes, 2 + 4);
    }

    #[test]
    fn two_hop_boundary_bills_each_hop_to_its_produced_format() {
        // [(8,16), (4,8)]: the 16→4 boundary chains via 8. At a 12-row
        // batch the 16→8 hop produces ceil(12·8/48) = 2 words and the
        // 8→4 hop ceil(12·4/48) = 1, per hidden column — each booked to
        // the format it produced, not all to the final one.
        let mut rng = XorShift64::new(0x2B0B);
        let layers = random_layers(&mut rng);
        let hidden_n = layers[0].n as u64;
        let sched = vec![LayerPrecision::new(8, 16), LayerPrecision::new(4, 8)];
        let engine = engine_for(layers, sched);
        let batch: Vec<Vec<i64>> = (0..12)
            .map(|_| (0..10).map(|_| rng.q_raw(8)).collect())
            .collect();
        let (_, stats) = engine.forward_batch(&batch);
        // Only the boundary's second hop produces 4-bit words.
        assert_eq!(stats.s2_passes_by_fmt[format_index(4)], hidden_n);
        // The first hop's 8-bit words are in the 8-bit bucket (together
        // with layer 1's 4→8 widen passes).
        assert!(stats.s2_passes_by_fmt[format_index(8)] >= 2 * hidden_n);
    }

    #[test]
    fn boundary_repack_is_billed_per_output_column() {
        // 2-layer uniform 8→16 model: the 16→8 boundary conversion of
        // each hidden column is billed as Stage-2 passes producing 8-bit
        // words: ceil(6·8/48) = 1 pass per column at a 6-row batch.
        let mut rng = XorShift64::new(0xB0B0);
        let layers = random_layers(&mut rng);
        let hidden_n = layers[0].n as u64;
        let engine = engine_uniform(layers, 8, 16);
        let batch: Vec<Vec<i64>> = (0..6)
            .map(|_| (0..10).map(|_| rng.q_raw(8)).collect())
            .collect();
        let (_, stats) = engine.forward_batch(&batch);
        assert_eq!(stats.s2_passes_by_fmt[format_index(8)], hidden_n);
    }

    #[test]
    fn conv_boundary_bills_conversions_like_packed_boundaries() {
        // A conv→dense boundary converts the same number of sub-words
        // through the same chain as a dense→dense boundary of equal row
        // count — the scalar staging is invisible to the counters.
        let mut rng = XorShift64::new(0xC0DE4);
        // conv 1x2x2 → 2ch 2x2 s1 p0: out 2 pixels... (2-2)/1+1 = 1 →
        // out 2x1x1, 2 features, prows = 1 pixel per image.
        let shape =
            ConvShape { cin: 1, h: 2, w: 2, cout: 2, kh: 2, kw: 2, stride: 1, pad: 0 };
        let conv = random_conv_for_shape(&mut rng, shape, 8);
        let dense_tail = QuantLayer::new(vec![vec![64], vec![-32]], 8);
        let ops = vec![LayerOp::Conv(conv), LayerOp::Dense(dense_tail.clone())];
        let model =
            CompiledModel::compile_stack(ops, uniform_schedule(8, 16, 2)).unwrap();
        let engine = PackedEngine::new(model);
        let batch: Vec<Vec<i64>> = (0..6)
            .map(|_| (0..4).map(|_| rng.q_raw(8)).collect())
            .collect();
        let (_, stats) = engine.forward_batch(&batch);
        // Boundary: 6 rows × 2 columns, 16→8 chain → ceil(6·8/48) = 1
        // pass per column, booked to the 8-bit bucket.
        assert_eq!(stats.s2_passes_by_fmt[format_index(8)], 2);
    }
}
