"""Property tests for the pinned plain-int semantics (`compile.defs`)."""

import math

import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # offline image — deterministic fallback
    from _hypothesis_compat import given, settings, st

from compile import defs

FORMATS = list(defs.FORMATS)


def q_range(bits):
    half = 1 << (bits - 1)
    return st.integers(min_value=-half, max_value=half - 1)


class TestCsd:
    @given(st.sampled_from([4, 6, 8, 12, 16]), st.data())
    @settings(max_examples=300)
    def test_roundtrip(self, y, data):
        m = data.draw(q_range(y))
        d = defs.csd_encode(m, y)
        assert len(d) == y
        assert defs.csd_decode(d) == m

    @given(st.sampled_from([4, 6, 8, 12, 16]), st.data())
    @settings(max_examples=300)
    def test_no_adjacent_nonzeros(self, y, data):
        m = data.draw(q_range(y))
        d = defs.csd_encode(m, y)
        for a, b in zip(d, d[1:]):
            assert a == 0 or b == 0

    def test_paper_example(self):
        # "0-01" = −4 + 1 = −3.
        assert defs.csd_encode(-3, 4) == [0, -1, 0, 1]

    @given(st.sampled_from([4, 8, 16]), st.data())
    @settings(max_examples=300)
    def test_zero_density_reasonable(self, y, data):
        m = data.draw(q_range(y))
        d = defs.csd_encode(m, y)
        nz = sum(1 for x in d if x != 0)
        assert nz <= math.ceil((y + 1) / 2)


class TestSchedule:
    @given(st.sampled_from([4, 6, 8, 12, 16]), st.data())
    @settings(max_examples=400)
    def test_exact_product_with_headroom(self, y, data):
        """Replaying the plan on a multiplicand with enough trailing
        zero bits must compute x·m exactly."""
        m = data.draw(q_range(y))
        x = 7919 << 32
        acc = 0
        for shift, sign in defs.schedule(m, y):
            acc = (acc + sign * x) >> shift
        assert acc == (x * m) >> (y - 1)

    @given(st.sampled_from([4, 8, 16]), st.data())
    @settings(max_examples=300)
    def test_plan_shape_constraints(self, y, data):
        m = data.draw(q_range(y))
        ops = defs.schedule(m, y)
        assert len(ops) <= defs.OPS_MAX
        for i, (shift, sign) in enumerate(ops):
            assert 0 <= shift <= defs.MAX_SHIFT
            assert sign in (-1, 0, 1)
            if shift == 0:
                assert sign != 0 and i == len(ops) - 1
            if sign == 0:
                assert shift >= 1

    def test_zero_multiplier_free(self):
        assert defs.schedule(0, 8) == []

    def test_minus_one_single_add(self):
        assert defs.schedule(-128, 8) == [(0, -1)]


class TestMulScalar:
    @given(st.sampled_from(FORMATS), st.data())
    @settings(max_examples=500)
    def test_accuracy_bound(self, bits, data):
        """Truncation error ≤ (#ops) ULPs; paper cites ~1% at 8 bits."""
        x = data.draw(q_range(bits))
        m = data.draw(q_range(bits))
        if x == -(1 << (bits - 1)) and m == -(1 << (bits - 1)):
            return  # −1 × −1 wrap corner
        got = defs.mul_scalar(x, m, bits, bits)
        truth = defs.from_q(x, bits) * defs.from_q(m, bits)
        nops = max(1, len(defs.schedule(m, bits)))
        assert abs(defs.from_q(got, bits) - truth) <= (nops + 1) * 2 ** -(bits - 1)

    def test_known_values(self):
        # 0.5 × 0.5 = 0.25 exactly at 8 bits.
        assert defs.mul_scalar(64, 64, 8, 8) == 32
        # x × −1 = −x (away from the wrap corner).
        assert defs.mul_scalar(100, -128, 8, 8) == -100
        # x × 0 = 0 (empty plan).
        assert defs.mul_scalar(-77, 0, 8, 8) == 0


class TestPack:
    @given(st.sampled_from(FORMATS), st.data())
    @settings(max_examples=200)
    def test_roundtrip(self, bits, data):
        fmt = defs.SimdFormat(bits)
        vals = [data.draw(q_range(bits)) for _ in range(fmt.lanes)]
        assert defs.unpack(defs.pack(vals, fmt), fmt) == vals

    @given(st.sampled_from(FORMATS), st.integers(1, 40), st.data())
    @settings(max_examples=100)
    def test_stream_roundtrip(self, bits, count, data):
        fmt = defs.SimdFormat(bits)
        vals = [data.draw(q_range(bits)) for _ in range(count)]
        words = defs.pack_stream(vals, fmt)
        assert defs.unpack_stream(words, fmt, count) == vals


class TestRepack:
    @given(st.sampled_from(FORMATS), st.sampled_from(FORMATS), st.data())
    @settings(max_examples=150)
    def test_widen_narrow_roundtrip(self, a, b, data):
        if a >= b:
            return
        fa = defs.SimdFormat(a)
        count = fa.lanes
        vals = [data.draw(q_range(a)) for _ in range(count)]
        words = defs.pack_stream(vals, fa)
        wide = defs.repack_stream(words, a, b, count)
        back = defs.repack_stream(wide, b, a, count)
        assert defs.unpack_stream(back, fa, count) == vals

    def test_chain_for_16_to_4(self):
        assert defs.conversion_chain(16, 4) == [(16, 8), (8, 4)]

    @given(st.sampled_from(FORMATS), st.sampled_from(FORMATS))
    def test_chain_hops_direct(self, a, b):
        for f, t in defs.conversion_chain(a, b):
            assert defs.is_direct(f, t)


class TestQuant:
    @given(st.floats(min_value=-0.999, max_value=0.93), st.sampled_from(FORMATS))
    @settings(max_examples=300)
    def test_roundtrip_error_half_ulp(self, v, bits):
        q = defs.to_q(v, bits)
        assert abs(defs.from_q(q, bits) - v) <= 2 ** -(bits - 1) / 2 + 1e-12

    def test_saturation(self):
        assert defs.to_q(1.5, 8) == 127
        assert defs.to_q(-7.0, 8) == -128
