//! Cross-language golden-vector checker.
//!
//! `python/compile/aot.py` emits `artifacts/golden.txt` from the Python
//! side of the pinned semantics; this module replays every line through
//! the Rust implementations. Any mismatch is a semantics drift between
//! the layers — the single most important invariant in the repo.

use std::fmt::Write as _;
use std::path::Path;

use crate::anyhow;

use crate::bits::format::SimdFormat;
use crate::bits::swar;
use crate::pipeline::stage1::mul_packed;
use crate::pipeline::stage2::repack_stream;

/// Outcome of a golden run.
#[derive(Debug, Default, Clone)]
pub struct GoldenReport {
    pub swar: usize,
    pub mul: usize,
    pub repack: usize,
    pub mlp_rows: usize,
    pub failures: Vec<String>,
}

impl std::fmt::Display for GoldenReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "golden: {} swar, {} mul, {} repack, {} mlp rows checked",
            self.swar, self.mul, self.repack, self.mlp_rows
        )?;
        if self.failures.is_empty() {
            write!(f, "ALL VECTORS MATCH")
        } else {
            writeln!(f, "{} FAILURES:", self.failures.len())?;
            for l in self.failures.iter().take(20) {
                writeln!(f, "  {l}")?;
            }
            Ok(())
        }
    }
}

impl GoldenReport {
    pub fn ok(&self) -> bool {
        self.failures.is_empty()
    }
}

fn parse_u64(s: &str) -> anyhow::Result<u64> {
    let s = s.trim();
    if let Some(hex) = s.strip_prefix("0x") {
        Ok(u64::from_str_radix(hex, 16)?)
    } else {
        Ok(s.parse()?)
    }
}

/// Check every vector in a golden file against the Rust implementations.
pub fn check_file(path: impl AsRef<Path>) -> anyhow::Result<GoldenReport> {
    let text = std::fs::read_to_string(path.as_ref())?;
    check_str(&text)
}

/// As [`check_file`] over in-memory text.
pub fn check_str(text: &str) -> anyhow::Result<GoldenReport> {
    let mut rep = GoldenReport::default();
    // MLP vectors are checked jointly at the end.
    let mut mlp_in: Vec<(usize, Vec<i64>)> = vec![];
    let mut mlp_out: Vec<(usize, Vec<i64>)> = vec![];

    for (lineno, line) in text.lines().enumerate() {
        let mut it = line.split_whitespace();
        let kind = match it.next() {
            Some(k) => k,
            None => continue,
        };
        let fail = |rep: &mut GoldenReport, msg: String| {
            let mut s = String::new();
            let _ = write!(s, "line {}: {msg}", lineno + 1);
            rep.failures.push(s);
        };
        match kind {
            "swar" => {
                let op = it.next().unwrap();
                let bits: u32 = it.next().unwrap().parse()?;
                let a = parse_u64(it.next().unwrap())?;
                let c = parse_u64(it.next().unwrap())?;
                let k: u32 = it.next().unwrap().parse()?;
                let want = parse_u64(it.next().unwrap())?;
                let fmt = SimdFormat::new(bits);
                let got = match op {
                    "add" => swar::swar_add(a, c, fmt),
                    "sub" => swar::swar_sub(a, c, fmt),
                    "sar" => swar::swar_sar(a, k, fmt),
                    "addsar" => swar::swar_add_sar(a, c, k, fmt),
                    "subsar" => swar::swar_sub_sar(a, c, k, fmt),
                    other => anyhow::bail!("unknown swar op {other}"),
                };
                rep.swar += 1;
                if got != want {
                    fail(&mut rep, format!("swar {op} {bits}b: got {got:#x} want {want:#x}"));
                }
            }
            "mul" => {
                let bits: u32 = it.next().unwrap().parse()?;
                let y: u32 = it.next().unwrap().parse()?;
                let m: i64 = it.next().unwrap().parse()?;
                let x = parse_u64(it.next().unwrap())?;
                let want = parse_u64(it.next().unwrap())?;
                let got = mul_packed(x, m, y, SimdFormat::new(bits));
                rep.mul += 1;
                if got != want {
                    fail(
                        &mut rep,
                        format!("mul {bits}b×{y}b m={m}: got {got:#x} want {want:#x}"),
                    );
                }
            }
            "repack" => {
                let fb: u32 = it.next().unwrap().parse()?;
                let tb: u32 = it.next().unwrap().parse()?;
                let count: usize = it.next().unwrap().parse()?;
                let input: Vec<u64> = it
                    .next()
                    .unwrap()
                    .split(',')
                    .map(parse_u64)
                    .collect::<Result<_, _>>()?;
                let want: Vec<u64> = it
                    .next()
                    .unwrap()
                    .split(',')
                    .map(parse_u64)
                    .collect::<Result<_, _>>()?;
                let got = repack_stream(&input, SimdFormat::new(fb), SimdFormat::new(tb), count);
                rep.repack += 1;
                if got != want {
                    fail(&mut rep, format!("repack {fb}->{tb}: got {got:x?} want {want:x?}"));
                }
            }
            "mlp_in" | "mlp_out" => {
                let row: usize = it.next().unwrap().parse()?;
                let vals: Vec<i64> = it
                    .next()
                    .unwrap()
                    .split(',')
                    .map(|v| v.parse::<i64>())
                    .collect::<Result<_, _>>()?;
                if kind == "mlp_in" {
                    mlp_in.push((row, vals));
                } else {
                    mlp_out.push((row, vals));
                }
            }
            "mlp_label" => { /* consumed by the e2e example, not here */ }
            other => anyhow::bail!("unknown golden kind {other} on line {}", lineno + 1),
        }
    }

    // MLP: replay through the Rust quantized-NN reference when the
    // weights file sits next to the golden file.
    if !mlp_in.is_empty() {
        let weights_path = Path::new("artifacts/mlp_weights.txt");
        if weights_path.exists() {
            let layers = crate::nn::weights::load_weight_file(weights_path)?;
            for ((ri, xin), (ro, want)) in mlp_in.iter().zip(mlp_out.iter()) {
                assert_eq!(ri, ro);
                let got = crate::nn::exec::mlp_forward_row(xin, &layers, 8, 16);
                rep.mlp_rows += 1;
                if &got != want {
                    rep.failures
                        .push(format!("mlp row {ri}: got {got:?} want {want:?}"));
                }
            }
        }
    }
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_hex_and_decimal() {
        assert_eq!(parse_u64("0xff").unwrap(), 255);
        assert_eq!(parse_u64("17").unwrap(), 17);
    }

    #[test]
    fn detects_mismatch() {
        let rep = check_str("mul 8 8 64 0x40 0x99\n").unwrap();
        assert!(!rep.ok());
    }

    #[test]
    fn accepts_correct_vector() {
        // 0.5 × 0.5 = 0.25: lane0 = 64 → 32.
        let rep = check_str("mul 8 8 64 0x40 0x20\n").unwrap();
        assert!(rep.ok(), "{rep}");
    }
}
