//! Quantized neural-network execution on the Soft SIMD semantics.
//!
//! `weights` loads the AOT-baked model; `exec` provides the scalar-int
//! reference forward pass (the semantic pivot shared with
//! `python/compile/model.py::mlp_forward_int`) and the packed execution
//! path that runs layers on the simulated pipeline through the
//! coordinator.

pub mod exec;
pub mod weights;

pub use exec::{mlp_forward_batch, mlp_forward_row, mlp_forward_row_mixed, requantize_activation};
pub use weights::{load_weight_file, quantize_stack, uniform_schedule, LayerPrecision, QuantLayer};
