//! Truncation-error analysis of the Soft SIMD multiplication
//! (Section III-B: "negligible even for very constrained bitwidths,
//! e.g. approximately 1% in the shown 8-bit example").

use crate::bits::fixed::from_q;
use crate::pipeline::stage1::mul_scalar;
use crate::workload::synth::XorShift64;

/// Aggregate multiply-error statistics at a given operand width pair.
#[derive(Debug, Clone, Copy)]
pub struct ErrorStats {
    pub x_bits: u32,
    pub y_bits: u32,
    /// Mean |relative error| over products with |truth| ≥ 0.1.
    pub mean_rel: f64,
    /// Max |absolute error| in value units.
    pub max_abs: f64,
    /// RMS absolute error.
    pub rms_abs: f64,
}

/// Monte-Carlo error statistics of the truncating multiply vs the exact
/// float product.
pub fn mul_error_stats(x_bits: u32, y_bits: u32, samples: usize, seed: u64) -> ErrorStats {
    let mut rng = XorShift64::new(seed);
    let mut rel_sum = 0.0;
    let mut rel_n = 0usize;
    let mut max_abs = 0.0f64;
    let mut sq_sum = 0.0;
    let half_x = 1i64 << (x_bits - 1);
    let half_y = 1i64 << (y_bits - 1);
    for _ in 0..samples {
        let x = rng.q_raw(x_bits);
        let m = rng.q_raw(y_bits);
        if x == -half_x && m == -half_y {
            continue; // −1 × −1 wrap corner
        }
        let got = from_q(mul_scalar(x, m, x_bits, y_bits), x_bits);
        let truth = from_q(x, x_bits) * from_q(m, y_bits);
        let abs = (got - truth).abs();
        max_abs = max_abs.max(abs);
        sq_sum += abs * abs;
        if truth.abs() >= 0.1 {
            rel_sum += abs / truth.abs();
            rel_n += 1;
        }
    }
    ErrorStats {
        x_bits,
        y_bits,
        mean_rel: rel_sum / rel_n.max(1) as f64,
        max_abs,
        rms_abs: (sq_sum / samples as f64).sqrt(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_one_percent_claim_at_8bit() {
        let s = mul_error_stats(8, 8, 20_000, 0xE44);
        assert!(
            s.mean_rel < 0.02,
            "8-bit mean relative error {} should be ≈1%",
            s.mean_rel
        );
    }

    #[test]
    fn error_shrinks_with_width() {
        let s4 = mul_error_stats(4, 4, 20_000, 1);
        let s8 = mul_error_stats(8, 8, 20_000, 2);
        let s16 = mul_error_stats(16, 16, 20_000, 3);
        assert!(s4.rms_abs > s8.rms_abs && s8.rms_abs > s16.rms_abs);
    }

    #[test]
    fn max_error_bounded_by_plan_length() {
        // Each op truncates < 1 ULP; plans are ≤ y ops.
        let s = mul_error_stats(8, 8, 10_000, 9);
        assert!(s.max_abs <= 9.0 / 128.0, "{}", s.max_abs);
    }
}
