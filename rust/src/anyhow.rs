//! Vendored, API-compatible subset of the `anyhow` crate.
//!
//! The build is fully offline (no crates.io registry in the image), so
//! the error plumbing the repo would normally take from `anyhow` is
//! reproduced here: a string-backed [`Error`], the [`Result`] alias, a
//! blanket `From<E: std::error::Error>` conversion for `?`, and the
//! `anyhow!` / `bail!` / `ensure!` macros. Call sites are byte-for-byte
//! what they would be against the real crate (`use crate::anyhow;` /
//! `use softsimd::anyhow;` instead of an extern dependency), so swapping
//! the real `anyhow` back in is a one-line Cargo.toml change.

/// A string-backed error value (the shim keeps the rendered message
/// only; the real crate would keep the source chain).
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable.
    pub fn msg(m: impl std::fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.msg)
    }
}

// Deliberately NOT `impl std::error::Error for Error`: that keeps the
// blanket conversion below coherent, exactly like the real crate.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` with the usual defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

#[doc(hidden)]
#[macro_export]
macro_rules! __softsimd_anyhow {
    ($($arg:tt)*) => {
        $crate::anyhow::Error::msg(::std::format!($($arg)*))
    };
}

#[doc(hidden)]
#[macro_export]
macro_rules! __softsimd_bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow::Error::msg(
            ::std::format!($($arg)*),
        ))
    };
}

#[doc(hidden)]
#[macro_export]
macro_rules! __softsimd_ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow::Error::msg(
                ::std::format!($($arg)*),
            ));
        }
    };
}

pub use crate::__softsimd_anyhow as anyhow;
pub use crate::__softsimd_bail as bail;
pub use crate::__softsimd_ensure as ensure;

#[cfg(test)]
mod tests {
    use super::Error;
    use crate::anyhow;

    fn parse(s: &str) -> anyhow::Result<u64> {
        anyhow::ensure!(!s.is_empty(), "empty input");
        if s == "boom" {
            anyhow::bail!("refused: {s}");
        }
        Ok(s.parse()?) // From<ParseIntError> via the blanket impl
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse("17").unwrap(), 17);
        let e = parse("x").unwrap_err();
        assert!(e.to_string().contains("invalid digit"), "{e}");
    }

    #[test]
    fn macros_render_messages() {
        assert_eq!(parse("").unwrap_err().to_string(), "empty input");
        assert_eq!(parse("boom").unwrap_err().to_string(), "refused: boom");
        let e = anyhow::anyhow!("v={}", 3);
        assert_eq!(format!("{e:#}"), "v=3");
        assert_eq!(format!("{e:?}"), "v=3");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
