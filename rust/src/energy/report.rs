//! Table rendering for the evaluation harness.

/// Render a simple aligned table.
pub fn table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let line = |out: &mut String, cells: &[String]| {
        for (i, c) in cells.iter().enumerate() {
            out.push_str(&format!("{:>w$}  ", c, w = widths[i]));
        }
        out.push('\n');
    };
    line(&mut out, &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    let total: usize = widths.iter().sum::<usize>() + widths.len() * 2;
    out.push_str(&"-".repeat(total));
    out.push('\n');
    for row in rows {
        line(&mut out, row);
    }
    out
}

/// Format µm² with thousands separators.
pub fn um2(v: f64) -> String {
    format!("{:.0}", v)
}

/// Format pJ with 3 decimals.
pub fn pj(v: f64) -> String {
    format!("{:.3}", v)
}

/// Percentage.
pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let t = table(
            &["name", "value"],
            &[
                vec!["a".into(), "1".into()],
                vec!["long-name".into(), "12345".into()],
            ],
        );
        assert!(t.contains("long-name"));
        assert!(t.lines().count() == 4);
    }
}
