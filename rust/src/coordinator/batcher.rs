//! Dynamic batching: group inference requests into packed batches.
//!
//! Soft SIMD packs the batch dimension into sub-words, so the natural
//! batch quantum is a multiple of the lane count (6 at 8-bit). The
//! batcher accumulates requests until it can fill `target_rows` rows or
//! a flush is forced (deadline/queue drain) — the classic
//! latency/throughput dial of serving systems.

use super::server::Request;

/// A formed batch: requests plus the row span each owns.
#[derive(Debug)]
pub struct Batch {
    pub requests: Vec<Request>,
    pub rows: usize,
}

/// Row-count batcher.
#[derive(Debug)]
pub struct Batcher {
    pending: Vec<Request>,
    pending_rows: usize,
    pub target_rows: usize,
    pub max_wait_polls: u32,
    idle_polls: u32,
}

impl Batcher {
    pub fn new(target_rows: usize, max_wait_polls: u32) -> Self {
        Batcher {
            pending: vec![],
            pending_rows: 0,
            target_rows,
            max_wait_polls,
            idle_polls: 0,
        }
    }

    pub fn pending_rows(&self) -> usize {
        self.pending_rows
    }

    /// Offer a request; returns a formed batch when the target fills.
    pub fn push(&mut self, req: Request) -> Option<Batch> {
        self.pending_rows += req.rows.len();
        self.pending.push(req);
        self.idle_polls = 0;
        if self.pending_rows >= self.target_rows {
            return self.flush();
        }
        None
    }

    /// Poll tick with no arrivals; flushes after `max_wait_polls` idle
    /// ticks so stragglers are not starved.
    pub fn tick(&mut self) -> Option<Batch> {
        if self.pending.is_empty() {
            return None;
        }
        self.idle_polls += 1;
        if self.idle_polls >= self.max_wait_polls {
            self.flush()
        } else {
            None
        }
    }

    /// Force out whatever is queued.
    pub fn flush(&mut self) -> Option<Batch> {
        if self.pending.is_empty() {
            return None;
        }
        self.idle_polls = 0;
        let requests = std::mem::take(&mut self.pending);
        let rows = std::mem::take(&mut self.pending_rows);
        Some(Batch { requests, rows })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, rows: usize) -> Request {
        Request { id, rows: vec![vec![0i64; 4]; rows] }
    }

    #[test]
    fn fills_to_target() {
        let mut b = Batcher::new(6, 4);
        assert!(b.push(req(1, 2)).is_none());
        assert!(b.push(req(2, 2)).is_none());
        let batch = b.push(req(3, 2)).expect("target reached");
        assert_eq!(batch.rows, 6);
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(b.pending_rows(), 0);
    }

    #[test]
    fn deadline_flush_prevents_starvation() {
        let mut b = Batcher::new(6, 3);
        assert!(b.push(req(1, 1)).is_none());
        assert!(b.tick().is_none());
        assert!(b.tick().is_none());
        let batch = b.tick().expect("deadline flush");
        assert_eq!(batch.rows, 1);
    }

    #[test]
    fn oversized_request_flushes_immediately() {
        let mut b = Batcher::new(4, 3);
        let batch = b.push(req(1, 9)).expect("flush");
        assert_eq!(batch.rows, 9);
    }

    #[test]
    fn empty_tick_is_noop() {
        let mut b = Batcher::new(4, 1);
        assert!(b.tick().is_none());
        assert!(b.flush().is_none());
    }
}
