//! Soft SIMD formats over the 48-bit datapath.
//!
//! A format is a sub-word bitwidth `b` dividing 48. Sub-word `i` occupies
//! bits `[i*b, (i+1)*b)` of the word and holds a two's-complement
//! `Q1.(b-1)` value. The per-format mask constants here are the software
//! image of the paper's `V_x` control vector (Fig. 4): `msb_mask` marks
//! the positions where carry propagation is killed and where the shifter's
//! sign-replication muxes sit; `lsb_mask` marks where the `+1` of a
//! subtraction is injected.



/// Width of the datapath evaluated in the paper (Section IV-A).
pub const DATAPATH_BITS: u32 = 48;

/// Mask selecting the 48 datapath bits inside the `u64` carrier.
pub const WORD_MASK: u64 = (1u64 << DATAPATH_BITS) - 1;

/// The sub-word widths supported by the design under study (Section III-C).
pub const FORMATS: [u32; 5] = [4, 6, 8, 12, 16];

/// Maximum coalesced shift distance per cycle (Section III-B: "up to
/// 3-bit patterns").
pub const MAX_SHIFT: u32 = 3;

/// A Soft SIMD format: the datapath partitioned into `lanes` sub-words of
/// `bits` bits each.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SimdFormat {
    /// Sub-word width in bits.
    pub bits: u32,
}

/// Position of `bits` inside [`FORMATS`] — the canonical index for
/// per-format tally arrays (`EngineStats`, `Metrics`). Panics on an
/// unsupported width, same contract as [`SimdFormat::new`].
#[inline]
pub fn format_index(bits: u32) -> usize {
    FORMATS
        .iter()
        .position(|&b| b == bits)
        .unwrap_or_else(|| panic!("unsupported Soft SIMD sub-word width {bits} (supported: {FORMATS:?})"))
}

/// Precomputed per-format mask tables, indexed by sub-word width.
/// Computed at compile time — the SWAR hot path must not rebuild masks
/// (DESIGN.md §9).
const fn tile(pattern: u64, bits: u32) -> u64 {
    let mut out = 0u64;
    let mut i = 0;
    while i < DATAPATH_BITS {
        out |= pattern << i;
        i += bits;
    }
    out & WORD_MASK
}

const MSB_MASKS: [u64; 17] = {
    let mut t = [0u64; 17];
    let mut i = 0;
    while i < FORMATS.len() {
        let b = FORMATS[i];
        t[b as usize] = tile(1u64 << (b - 1), b);
        i += 1;
    }
    t
};

const LSB_MASKS: [u64; 17] = {
    let mut t = [0u64; 17];
    let mut i = 0;
    while i < FORMATS.len() {
        let b = FORMATS[i];
        t[b as usize] = tile(1, b);
        i += 1;
    }
    t
};

/// KEEP_MASKS[k][bits]: low `bits - k` bits of each slot, k ∈ 1..=3.
const KEEP_MASKS: [[u64; 17]; 4] = {
    let mut t = [[0u64; 17]; 4];
    let mut k = 1;
    while k <= 3 {
        let mut i = 0;
        while i < FORMATS.len() {
            let b = FORMATS[i];
            t[k][b as usize] = tile((1u64 << (b - k as u32)) - 1, b);
            i += 1;
        }
        k += 1;
    }
    t
};

impl SimdFormat {
    /// Create a format; panics unless `bits` divides 48 and is supported.
    pub fn new(bits: u32) -> Self {
        assert!(
            FORMATS.contains(&bits),
            "unsupported Soft SIMD sub-word width {bits} (supported: {FORMATS:?})"
        );
        SimdFormat { bits }
    }

    /// All supported formats.
    pub fn all() -> impl Iterator<Item = SimdFormat> {
        FORMATS.iter().map(|&b| SimdFormat::new(b))
    }

    /// Number of sub-words per 48-bit word.
    #[inline]
    pub fn lanes(self) -> u32 {
        DATAPATH_BITS / self.bits
    }

    /// Mask with the MSB of every sub-word set (carry-kill / sign-mux
    /// positions; `V_x = 0` positions in Fig. 4).
    #[inline(always)]
    pub fn msb_mask(self) -> u64 {
        MSB_MASKS[self.bits as usize]
    }

    /// Mask with the LSB of every sub-word set (`+1` injection positions
    /// for subtraction).
    #[inline(always)]
    pub fn lsb_mask(self) -> u64 {
        LSB_MASKS[self.bits as usize]
    }

    /// Mask with all bits of every sub-word set (always `WORD_MASK` for
    /// exact divisors; kept for clarity/extensibility).
    #[inline]
    pub fn full_mask(self) -> u64 {
        WORD_MASK
    }

    /// Mask keeping, in each sub-word slot, the low `bits - k` bits:
    /// the positions a `k`-bit right shift may legitimately fill from the
    /// same sub-word. The excluded top-`k` positions are re-filled by
    /// sign replication.
    #[inline(always)]
    pub fn keep_mask(self, k: u32) -> u64 {
        debug_assert!(k >= 1 && k <= MAX_SHIFT && k < self.bits);
        KEEP_MASKS[k as usize][self.bits as usize]
    }

    /// Mask of one sub-word slot `i`.
    #[inline]
    pub fn lane_mask(self, i: u32) -> u64 {
        debug_assert!(i < self.lanes());
        ((1u64 << self.bits) - 1) << (i * self.bits)
    }

    /// Tile `pattern` (confined to the low `bits` bits) across all lanes.
    #[inline]
    pub fn repeat(self, pattern: u64) -> u64 {
        debug_assert_eq!(pattern & !((1u64 << self.bits) - 1), 0);
        let mut out = 0u64;
        let mut i = 0;
        while i < DATAPATH_BITS {
            out |= pattern << i;
            i += self.bits;
        }
        out & WORD_MASK
    }
}

impl std::fmt::Display for SimdFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}b", self.lanes(), self.bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lanes_cover_datapath() {
        for f in SimdFormat::all() {
            assert_eq!(f.lanes() * f.bits, DATAPATH_BITS);
        }
    }

    #[test]
    fn msb_mask_has_one_bit_per_lane() {
        for f in SimdFormat::all() {
            assert_eq!(f.msb_mask().count_ones(), f.lanes());
            assert_eq!(f.lsb_mask().count_ones(), f.lanes());
            // MSB of lane i is at bit (i+1)*b - 1.
            for i in 0..f.lanes() {
                assert!(f.msb_mask() & (1u64 << ((i + 1) * f.bits - 1)) != 0);
                assert!(f.lsb_mask() & (1u64 << (i * f.bits)) != 0);
            }
        }
    }

    #[test]
    fn keep_mask_excludes_top_k_bits() {
        for f in SimdFormat::all() {
            for k in 1..=MAX_SHIFT {
                let keep = f.keep_mask(k);
                for i in 0..f.lanes() {
                    let lane = f.lane_mask(i);
                    let kept = (keep & lane).count_ones();
                    assert_eq!(kept, f.bits - k, "fmt {f} k {k} lane {i}");
                }
            }
        }
    }

    #[test]
    fn lane_masks_partition_word() {
        for f in SimdFormat::all() {
            let mut acc = 0u64;
            for i in 0..f.lanes() {
                let m = f.lane_mask(i);
                assert_eq!(acc & m, 0, "lanes overlap");
                acc |= m;
            }
            assert_eq!(acc, WORD_MASK);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_unsupported_width() {
        SimdFormat::new(5);
    }

    #[test]
    fn format_index_matches_formats_order() {
        for (i, &b) in FORMATS.iter().enumerate() {
            assert_eq!(format_index(b), i);
        }
    }

    #[test]
    #[should_panic]
    fn format_index_rejects_unsupported_width() {
        format_index(5);
    }
}
