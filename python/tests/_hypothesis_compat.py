"""Deterministic fallback for the `hypothesis` API surface these tests
use, for offline images where the real package is unavailable.

Implements ``given``/``settings`` and the strategies actually consumed
(``integers``, ``floats``, ``sampled_from``, ``data``) as a seeded
exhaustive-ish random sweep: every ``@given`` test runs ``max_examples``
deterministic cases (seeded from the test's qualified name), so failures
are reproducible. No shrinking, no database — a test failure reports the
drawn values via the assertion message only.

Usage (at the top of a test module)::

    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ModuleNotFoundError:
        from _hypothesis_compat import given, settings, st
"""

import functools
import inspect
import random
import zlib

__all__ = ["given", "settings", "st", "HealthCheck"]

_DEFAULT_EXAMPLES = 100


class _Strategy:
    """A draw function wrapper (mirrors hypothesis' SearchStrategy)."""

    def __init__(self, draw):
        self._draw = draw

    def draw(self, rnd):
        return self._draw(rnd)


class _DataObject:
    """Mirror of hypothesis' interactive ``data()`` object."""

    def __init__(self, rnd):
        self._rnd = rnd

    def draw(self, strategy):
        return strategy.draw(self._rnd)


class _DataStrategy(_Strategy):
    def __init__(self):
        super().__init__(lambda rnd: _DataObject(rnd))


class _St:
    """The `strategies` module surface used by these tests."""

    @staticmethod
    def integers(min_value=None, max_value=None):
        lo = -(2**64) if min_value is None else min_value
        hi = 2**64 if max_value is None else max_value
        return _Strategy(lambda rnd: rnd.randint(lo, hi))

    @staticmethod
    def floats(min_value=None, max_value=None, allow_nan=False, allow_infinity=False):
        lo = -1e308 if min_value is None else min_value
        hi = 1e308 if max_value is None else max_value
        return _Strategy(lambda rnd: rnd.uniform(lo, hi))

    @staticmethod
    def sampled_from(elements):
        seq = list(elements)
        if not seq:
            raise ValueError("sampled_from needs a non-empty sequence")
        return _Strategy(lambda rnd: rnd.choice(seq))

    @staticmethod
    def booleans():
        return _Strategy(lambda rnd: rnd.random() < 0.5)

    @staticmethod
    def data():
        return _DataStrategy()


st = _St()


class HealthCheck:
    """Accepted and ignored (API compatibility)."""

    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"


def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_ignored):
    """Records ``max_examples`` on the function; other knobs are no-ops."""

    def decorate(fn):
        fn._compat_max_examples = max_examples
        return fn

    return decorate


def given(*strategies, **kw_strategies):
    """Run the test over ``max_examples`` deterministic random draws."""

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(
                wrapper,
                "_compat_max_examples",
                getattr(fn, "_compat_max_examples", _DEFAULT_EXAMPLES),
            )
            seed_base = zlib.crc32(fn.__qualname__.encode())
            for i in range(n):
                rnd = random.Random((seed_base << 20) + i)
                drawn = [s.draw(rnd) for s in strategies]
                kw_drawn = {k: s.draw(rnd) for k, s in kw_strategies.items()}
                fn(*args, *drawn, **kwargs, **kw_drawn)

        # Hide the strategy-filled parameters from pytest, which would
        # otherwise look for fixtures named after them. Strategies fill
        # the trailing positional parameters (hypothesis semantics), so
        # only the leading ones (e.g. `self`) remain visible.
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        keep = len(params) - len(strategies) - len(kw_strategies)
        wrapper.__signature__ = sig.replace(parameters=params[:keep])
        try:
            del wrapper.__wrapped__
        except AttributeError:
            pass
        return wrapper

    return decorate
