//! Runtime lane sanitizer — the dynamic oracle of the static
//! lane-safety verifier (DESIGN.md §14; `--features lanecheck`).
//!
//! When the `lanecheck` feature is enabled, the SWAR primitives
//! ([`crate::bits::swar`]) report every lane whose value actually
//! wrapped during an add/sub/neg, and the pipeline stages check that
//! every word they produce stays inside the 48-bit datapath mask.
//! Violations are *recorded, never raised*: the SWAR layer's wrapping
//! behavior is architecturally defined (the `−1 × −1` corner is even
//! exercised on purpose by its unit tests), so the sanitizer is a
//! tracing tool — tests and harnesses bracket a region with
//! [`reset`]/[`count`] and decide for themselves whether a wrap was
//! legitimate.
//!
//! The two directions of the oracle:
//!
//! * **Soundness.** Schedules the static verifier accepts must keep
//!   [`count`] at zero over randomized batches — any violation would
//!   disprove the abstract interpretation.
//! * **Tightness.** Schedules it rejects ship a synthesized
//!   counterexample input; executing that input must make [`count`]
//!   positive — the rejection is demonstrably not a false alarm.
//!
//! State is thread-local (workers sanitize independently) and the
//! detailed log is capped at [`LOG_CAP`] entries; the total counter is
//! never capped.

use std::cell::{Cell, RefCell};

use crate::bits::format::WORD_MASK;

/// Maximum number of [`Violation`] records retained per thread; the
/// total count keeps incrementing past the cap.
pub const LOG_CAP: usize = 1024;

/// What kind of lane invariant was violated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ViolationKind {
    /// A lane wrapped during a SWAR addition.
    AddOverflow,
    /// A lane wrapped during a SWAR subtraction.
    SubOverflow,
    /// A minimum-value lane wrapped during a SWAR negation.
    NegOverflow,
    /// A produced word had bits set above the 48-bit datapath mask.
    MaskViolation,
}

/// One recorded lane violation.
#[derive(Debug, Clone)]
pub struct Violation {
    /// The invariant that failed.
    pub kind: ViolationKind,
    /// Sub-word width of the operation.
    pub bits: u32,
    /// For overflows: the MSB mask of the lanes that wrapped. For mask
    /// violations: the out-of-datapath bits.
    pub lanes: u64,
    /// The pipeline context last announced via [`set_context`].
    pub context: &'static str,
}

thread_local! {
    static VIOLATIONS: RefCell<Vec<Violation>> = const { RefCell::new(Vec::new()) };
    static TOTAL: Cell<u64> = const { Cell::new(0) };
    static CONTEXT: Cell<&'static str> = const { Cell::new("") };
}

/// Clear this thread's violation log and counter.
pub fn reset() {
    VIOLATIONS.with(|v| v.borrow_mut().clear());
    TOTAL.with(|t| t.set(0));
}

/// Total violations recorded on this thread since the last [`reset`]
/// (not capped).
pub fn count() -> u64 {
    TOTAL.with(|t| t.get())
}

/// Drain this thread's detailed violation log (at most [`LOG_CAP`]
/// entries; the counter is left untouched).
pub fn take() -> Vec<Violation> {
    VIOLATIONS.with(|v| std::mem::take(&mut *v.borrow_mut()))
}

/// Announce the pipeline region subsequent violations belong to
/// (purely diagnostic — shows up in [`Violation::context`]).
pub fn set_context(ctx: &'static str) {
    CONTEXT.with(|c| c.set(ctx));
}

/// Record `lanes` violating lanes of an operation (no-op when zero).
/// Never panics — see the module docs for why recording beats raising.
pub(crate) fn note(kind: ViolationKind, bits: u32, lanes: u64) {
    if lanes == 0 {
        return;
    }
    TOTAL.with(|t| t.set(t.get() + 1));
    let context = CONTEXT.with(|c| c.get());
    VIOLATIONS.with(|v| {
        let mut log = v.borrow_mut();
        if log.len() < LOG_CAP {
            log.push(Violation { kind, bits, lanes, context });
        }
    });
}

/// Check a produced word against the 48-bit datapath mask, recording a
/// [`ViolationKind::MaskViolation`] if any higher bit is set.
pub(crate) fn check_word(w: u64, bits: u32) {
    note(ViolationKind::MaskViolation, bits, w & !WORD_MASK);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recording_is_counted_logged_and_resettable() {
        reset();
        assert_eq!(count(), 0);
        note(ViolationKind::AddOverflow, 8, 0); // zero lanes: no-op
        assert_eq!(count(), 0);
        set_context("unit-test");
        note(ViolationKind::AddOverflow, 8, 0x80);
        check_word(1u64 << 50, 8);
        assert_eq!(count(), 2);
        let log = take();
        assert_eq!(log.len(), 2);
        assert_eq!(log[0].kind, ViolationKind::AddOverflow);
        assert_eq!(log[0].context, "unit-test");
        assert_eq!(log[1].kind, ViolationKind::MaskViolation);
        assert_eq!(log[1].lanes, 1u64 << 50);
        // take() drained the log but not the counter; reset clears both.
        assert_eq!(count(), 2);
        reset();
        assert_eq!(count(), 0);
        assert!(take().is_empty());
    }

    #[test]
    fn log_caps_but_counter_does_not() {
        reset();
        for _ in 0..(LOG_CAP + 10) {
            note(ViolationKind::MaskViolation, 4, 1);
        }
        assert_eq!(count(), LOG_CAP as u64 + 10);
        assert_eq!(take().len(), LOG_CAP);
        reset();
    }
}
