//! The immutable, shareable serving model: weights + precompiled CSD
//! multiply plans + packing metadata, built **once** and handed to every
//! PE worker behind an `Arc` (DESIGN.md §8).
//!
//! This is the schedule-amortization idea of the paper's control path
//! (the CSD plan is a property of the *multiplier value*, not of the
//! operand stream): compiling the per-weight shift-add programs is the
//! expensive, quantization-dependent step, so it must happen off the
//! per-request critical path and exactly once per deployed model — not
//! once per worker, as the original demo loop did.
//!
//! Since the engine went format-polymorphic (DESIGN.md §10), the
//! compiled model also carries the *precision schedule* — one
//! [`LayerPrecision`] per layer — together with the precomputed Stage-2
//! conversion chain for every layer boundary, and the batch quantum that
//! keeps every packed word full at every per-layer format. All of it is
//! validated here, at compile, so a malformed model (empty stack,
//! non-chaining dims, unsupported or inverted format pair) is an error
//! for its builder — never a panic inside a PE worker.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::anyhow;
use crate::bits::format::SimdFormat;
use crate::csd::flat::PlanArena;
use crate::csd::schedule::MulPlan;
use crate::nn::weights::{uniform_schedule, LayerPrecision, QuantLayer};
use crate::pipeline::stage2::conversion_chain;

/// Process-wide count of [`CompiledModel::compile`] runs. Exists so
/// tests can assert that plan compilation happens exactly once per
/// model no matter how many PE workers serve it.
pub static PLAN_COMPILATIONS: AtomicU64 = AtomicU64::new(0);

/// An immutable compiled model: quantized layers, per-layer serving
/// precision, plus every per-weight [`MulPlan`] and per-boundary
/// Stage-2 conversion chain, shared across all PE workers via [`Arc`].
#[derive(Debug)]
pub struct CompiledModel {
    layers: Vec<QuantLayer>,
    /// `plans[layer][k][n]`, precompiled for every weight — the
    /// inspectable compilation artifact (oracles, tests, billing
    /// cross-checks).
    plans: Vec<Vec<Vec<MulPlan>>>,
    /// The same plans flattened into one contiguous SoA micro-op buffer
    /// — the execution artifact the engine's hot loop runs
    /// (DESIGN.md §11).
    arena: PlanArena,
    /// One activation/accumulator format pair per layer.
    schedule: Vec<LayerPrecision>,
    /// `chains[li]`: the crossbar hop chain converting layer `li`'s
    /// accumulator stream into layer `li+1`'s activation format
    /// (`layers.len() - 1` entries; empty chain = Stage-2 bypass).
    chains: Vec<Vec<(SimdFormat, SimdFormat)>>,
    /// Rows per full packed batch: the LCM of every layer's activation
    /// and accumulator lane counts, so no layer ever sees a partial
    /// final word (6 for the uniform 8→16 schedule, up to 24 mixed).
    batch_quantum: usize,
    /// Total Stage-1 cycles of one forward pass per packed word column
    /// (sum of plan cycles over all weights) — scheduling metadata for
    /// load estimates.
    cycles_per_word: u64,
    /// Count of zero weights (zero-skipped at execution).
    zero_weights: u64,
}

fn lcm(a: usize, b: usize) -> usize {
    let gcd = |mut x: usize, mut y: usize| {
        while y != 0 {
            (x, y) = (y, x % y);
        }
        x
    };
    a / gcd(a, b) * b
}

impl CompiledModel {
    /// Compile a uniform-precision model (every layer at
    /// `in_bits → acc_bits`, the seed engine's only mode). Call once per
    /// model; clone the returned [`Arc`], never the model.
    pub fn compile(
        layers: Vec<QuantLayer>,
        in_bits: u32,
        acc_bits: u32,
    ) -> anyhow::Result<Arc<CompiledModel>> {
        let schedule = uniform_schedule(in_bits, acc_bits, layers.len());
        CompiledModel::compile_scheduled(layers, schedule)
    }

    /// Compile a mixed-precision model: layer `li` consumes
    /// `schedule[li].in_bits` activations and produces
    /// `schedule[li].acc_bits` accumulators; boundary conversion chains
    /// are precomputed here so workers never run the BFS. All structural
    /// validation happens here (DESIGN.md §10).
    pub fn compile_scheduled(
        layers: Vec<QuantLayer>,
        schedule: Vec<LayerPrecision>,
    ) -> anyhow::Result<Arc<CompiledModel>> {
        anyhow::ensure!(!layers.is_empty(), "model needs at least one layer");
        anyhow::ensure!(
            layers.len() == schedule.len(),
            "{} layers but {} precision entries",
            layers.len(),
            schedule.len()
        );
        let mut batch_quantum = 1usize;
        for (li, (layer, p)) in layers.iter().zip(&schedule).enumerate() {
            p.validate()
                .map_err(|e| anyhow::anyhow!("layer {li}: {e}"))?;
            anyhow::ensure!(
                crate::bits::format::FORMATS.contains(&layer.bits),
                "layer {li}: weight width {} is not a Soft SIMD format",
                layer.bits
            );
            anyhow::ensure!(
                layer.k > 0 && layer.n > 0,
                "layer {li}: degenerate shape {}x{}",
                layer.k,
                layer.n
            );
            if li > 0 {
                anyhow::ensure!(
                    layers[li - 1].n == layer.k,
                    "layer {li}: input width {} != previous layer's output width {}",
                    layer.k,
                    layers[li - 1].n
                );
            }
            batch_quantum = lcm(batch_quantum, p.in_fmt().lanes() as usize);
            batch_quantum = lcm(batch_quantum, p.acc_fmt().lanes() as usize);
        }
        let chains = schedule
            .windows(2)
            .map(|w| conversion_chain(w[0].acc_fmt(), w[1].in_fmt()))
            .collect();
        PLAN_COMPILATIONS.fetch_add(1, Ordering::SeqCst);
        let plans = crate::nn::exec::precompute_plans(&layers);
        let mut cycles_per_word = 0u64;
        let mut zero_weights = 0u64;
        for layer_plans in &plans {
            for row in layer_plans {
                for plan in row {
                    if plan.ops.is_empty() {
                        zero_weights += 1;
                    } else {
                        cycles_per_word += plan.cycles() as u64;
                    }
                }
            }
        }
        let arena = PlanArena::build(&plans);
        Ok(Arc::new(CompiledModel {
            layers,
            plans,
            arena,
            schedule,
            chains,
            batch_quantum,
            cycles_per_word,
            zero_weights,
        }))
    }

    pub fn layers(&self) -> &[QuantLayer] {
        &self.layers
    }

    /// The precompiled plan for layer `li`, weight `(k, n)`.
    #[inline]
    pub fn plan(&self, li: usize, k: usize, n: usize) -> &MulPlan {
        &self.plans[li][k][n]
    }

    /// The flattened micro-op arena the serving engine executes
    /// (one byte per Stage-1 cycle; column-adjacent plan headers).
    #[inline]
    pub fn flat(&self) -> &PlanArena {
        &self.arena
    }

    /// The full precision schedule, one entry per layer.
    pub fn schedule(&self) -> &[LayerPrecision] {
        &self.schedule
    }

    /// Layer `li`'s activation/accumulator format pair.
    #[inline]
    pub fn precision(&self, li: usize) -> LayerPrecision {
        self.schedule[li]
    }

    /// The precomputed crossbar chain converting layer `li`'s
    /// accumulators into layer `li+1`'s activations (empty = bypass).
    #[inline]
    pub fn boundary_chain(&self, li: usize) -> &[(SimdFormat, SimdFormat)] {
        &self.chains[li]
    }

    /// Activation width (bits) of the first layer — what requests
    /// arrive quantized to.
    pub fn in_bits(&self) -> u32 {
        self.schedule[0].in_bits
    }

    /// Accumulator width (bits) of the last layer — what responses
    /// carry.
    pub fn acc_bits(&self) -> u32 {
        self.schedule[self.schedule.len() - 1].acc_bits
    }

    pub fn in_fmt(&self) -> SimdFormat {
        self.schedule[0].in_fmt()
    }

    pub fn acc_fmt(&self) -> SimdFormat {
        self.schedule[self.schedule.len() - 1].acc_fmt()
    }

    /// Activation width of the first layer (row length of a request).
    pub fn input_width(&self) -> usize {
        self.layers[0].k
    }

    /// Rows per full packed batch: batches padded to a multiple of this
    /// keep every packed word full at every layer's format (6 for the
    /// uniform 8→16 schedule).
    pub fn batch_quantum(&self) -> usize {
        self.batch_quantum
    }

    /// Stage-1 cycles one packed word column costs across the whole
    /// forward pass (load-estimate metadata).
    pub fn cycles_per_word(&self) -> u64 {
        self.cycles_per_word
    }

    pub fn zero_weights(&self) -> u64 {
        self.zero_weights
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layers() -> Vec<QuantLayer> {
        vec![
            QuantLayer::new(vec![vec![64, 0], vec![-32, 127]], 8),
            QuantLayer::new(vec![vec![5], vec![-9]], 8),
        ]
    }

    #[test]
    fn compile_counts_and_metadata() {
        let before = PLAN_COMPILATIONS.load(Ordering::SeqCst);
        let m = CompiledModel::compile(layers(), 8, 16).unwrap();
        assert_eq!(PLAN_COMPILATIONS.load(Ordering::SeqCst), before + 1);
        assert_eq!(m.input_width(), 2);
        assert_eq!(m.batch_quantum(), 6); // lcm(6 @8b, 3 @16b)
        assert_eq!(m.zero_weights(), 1);
        assert!(m.cycles_per_word() > 0);
        assert_eq!(m.plan(0, 0, 0).ops.len(), m.layers()[0].plan(0, 0).ops.len());
        assert_eq!(m.boundary_chain(0), &[(SimdFormat::new(16), SimdFormat::new(8))]);
    }

    #[test]
    fn flat_arena_mirrors_the_plan_tables() {
        let m = CompiledModel::compile(layers(), 8, 16).unwrap();
        let arena = m.flat();
        for (li, layer) in m.layers().iter().enumerate() {
            for k in 0..layer.k {
                for n in 0..layer.n {
                    let plan = m.plan(li, k, n);
                    let h = arena.header(li, k, n);
                    assert_eq!(h.cycles as usize, plan.cycles(), "({li},{k},{n})");
                    assert_eq!(h.adds as usize, plan.adds());
                    let decoded: Vec<_> = arena
                        .ops(h)
                        .iter()
                        .map(|&b| crate::csd::flat::decode_op(b))
                        .collect();
                    assert_eq!(decoded, plan.ops);
                }
            }
        }
        // Column adjacency: layer 0 column 0 holds plans (k=0,n=0),(k=1,n=0).
        let col = arena.column(0, 0);
        assert_eq!(col.len(), 2);
        assert_eq!(col[0], arena.header(0, 0, 0));
        assert_eq!(col[1], arena.header(0, 1, 0));
    }

    #[test]
    fn rejects_empty_model_as_error_not_panic() {
        let err = CompiledModel::compile(vec![], 8, 16).expect_err("empty stack");
        assert!(err.to_string().contains("at least one layer"), "{err}");
    }

    #[test]
    fn rejects_malformed_schedules_and_shapes() {
        // Inverted precision pair (accumulator narrower than input).
        let err = CompiledModel::compile(layers(), 16, 8).expect_err("inverted pair");
        assert!(err.to_string().contains("narrower"), "{err}");
        // Schedule length mismatch.
        let err = CompiledModel::compile_scheduled(layers(), uniform_schedule(8, 16, 3))
            .expect_err("length mismatch");
        assert!(err.to_string().contains("precision entries"), "{err}");
        // Non-chaining layer dims.
        let bad = vec![
            QuantLayer::new(vec![vec![64, 0], vec![-32, 127]], 8), // 2 -> 2
            QuantLayer::new(vec![vec![5]], 8),                     // 1 -> 1
        ];
        let err = CompiledModel::compile(bad, 8, 16).expect_err("non-chaining dims");
        assert!(err.to_string().contains("output width"), "{err}");
    }

    #[test]
    fn mixed_schedule_metadata() {
        let sched = vec![LayerPrecision::new(4, 8), LayerPrecision::new(8, 16)];
        let m = CompiledModel::compile_scheduled(layers(), sched).unwrap();
        // lanes: 12 (4b in) / 6 (8b acc) / 6 (8b in) / 3 (16b acc).
        assert_eq!(m.batch_quantum(), 12);
        assert_eq!(m.in_bits(), 4);
        assert_eq!(m.acc_bits(), 16);
        // Boundary 8→8 is a bypass: empty chain.
        assert!(m.boundary_chain(0).is_empty());
        // A 2-hop boundary is precomputed as such.
        let sched = vec![LayerPrecision::new(8, 16), LayerPrecision::new(4, 8)];
        let m = CompiledModel::compile_scheduled(layers(), sched).unwrap();
        assert_eq!(m.boundary_chain(0).len(), 2, "16→4 chains via 8");
    }
}
