//! `softsimd serve` — the coordinator demo loop on the standard
//! synthetic-digits model (the AOT-baked MLP when artifacts exist, a
//! locally-quantized equivalent otherwise).

use std::time::Instant;

use crate::anyhow;

use super::cost::CostTable;
use super::model::CompiledModel;
use super::server::{Coordinator, Request, ServeConfig};
use crate::nn::exec::argmax_class;
use crate::workload::synth::Digits;

/// Serve `n` single-image requests; print accuracy/latency/throughput.
pub fn serve_demo(n: usize) -> anyhow::Result<()> {
    let weights_path = std::path::Path::new("artifacts/mlp_weights.txt");
    anyhow::ensure!(
        weights_path.exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let layers = crate::nn::weights::load_weight_file(weights_path)?;
    println!("characterizing pipeline energy at 1 GHz…");
    let cost = CostTable::characterize(1000.0);
    println!(
        "pipeline area {:.0} µm²; stage-1 ≈ {:.3} pJ/cycle @8b",
        cost.area_um2,
        cost.s1_pj(crate::bits::format::SimdFormat::new(8))
    );
    let model = CompiledModel::compile(layers, 8, 16)?;
    let digits = Digits::standard();
    let (xs, ys) = digits.sample(n, 0.3, 0x5E21E);

    let mut coord = Coordinator::start(model, ServeConfig::new(4, 12), cost)?;
    let t0 = Instant::now();
    for (id, row) in xs.iter().enumerate() {
        coord.submit(Request { id: id as u64, rows: vec![row.clone()] })?;
    }
    let responses = coord.drain()?;
    let wall = t0.elapsed();

    let mut correct = 0;
    for resp in &responses {
        if argmax_class(&resp.logits[0], 10) == ys[resp.id as usize] {
            correct += 1;
        }
    }
    println!(
        "served {n} requests in {:.2} ms ({:.0} req/s), accuracy {:.1}%",
        wall.as_secs_f64() * 1e3,
        n as f64 / wall.as_secs_f64(),
        correct as f64 / n as f64 * 100.0
    );
    println!("{}", coord.metrics.report());
    coord.shutdown();
    Ok(())
}
