//! The configurable adder of Fig. 4a: a 48-bit adder whose carry chain
//! is killed at sub-word MSB positions (`V_x = 0`) and which injects the
//! `+1` of two's-complement subtraction at every sub-word LSB.
//!
//! Two structural variants:
//! * `ripple` — one FA slice per bit with the boundary gating of
//!   Fig. 4a; minimal area, depth ∝ 48.
//! * `carry_select` — 4-bit blocks computed for both carry-in values and
//!   selected by a short mux chain (what synthesis produces under a
//!   tight clock); ~1.8× the area, ~¼ the depth. The synthesis model
//!   (`energy::model`) picks the variant per timing constraint.
//!
//! Netlist interface (input order):
//!   a[48], c[48], add_en, sub, m[48] (sub-word MSB mask = ¬V_x), l[48]
//!   (sub-word LSB mask)
//! Outputs: sum[48], ovf[48] (carry-in ⊕ carry-out per bit; consumed by
//! the fused shifter's sign-correction muxes at MSB positions).

use super::build::NetBuilder;
use super::gate::{Netlist, NodeId};

pub struct AdderIo {
    pub a: Vec<NodeId>,
    pub c: Vec<NodeId>,
    pub add_en: NodeId,
    pub sub: NodeId,
    pub m: Vec<NodeId>,
    pub l: Vec<NodeId>,
}

/// Declare the standard adder inputs on `b`.
pub fn declare_inputs(b: &mut NetBuilder, width: usize) -> AdderIo {
    AdderIo {
        a: b.inputs(width),
        c: b.inputs(width),
        add_en: b.input(),
        sub: b.input(),
        m: b.inputs(width),
        l: b.inputs(width),
    }
}

/// Emit the ripple slices; returns (sum, ovf) nets.
///
/// Per bit `i`:
///   c_eff  = (c_i & add_en) ⊕ sub          (operand gate + complement)
///   cin_i  = (carry_{i-1} & ¬m_{i-1}) | (sub & add_en & l_i)
///   sum_i, carry_i = FA(a_i, c_eff, cin_i)
///   ovf_i  = cin_i ⊕ carry_i ... at the MSB of a lane the true
///            (b+1)-bit sign is sum_i ⊕ ovf_i.
pub fn build_ripple(b: &mut NetBuilder, io: &AdderIo) -> (Vec<NodeId>, Vec<NodeId>) {
    let width = io.a.len();
    let sub_gated = b.and2(io.sub, io.add_en);
    let mut sums = Vec::with_capacity(width);
    let mut ovfs = Vec::with_capacity(width);
    let mut carry: Option<NodeId> = None;
    let mut prev_m: Option<NodeId> = None;
    for i in 0..width {
        let c_gated = b.and2(io.c[i], io.add_en);
        let c_eff = b.xor2(c_gated, sub_gated);
        let inject = b.and2(sub_gated, io.l[i]);
        let cin = match (carry, prev_m) {
            (Some(cy), Some(pm)) => {
                let v = b.not(pm); // V_x: propagate unless previous bit is a lane MSB
                let kept = b.and2(cy, v);
                b.or2(kept, inject)
            }
            _ => inject,
        };
        let (sum, cout) = b.full_adder(io.a[i], c_eff, cin);
        let ovf = b.xor2(cin, cout);
        sums.push(sum);
        ovfs.push(ovf);
        carry = Some(cout);
        prev_m = Some(io.m[i]);
    }
    (sums, ovfs)
}

/// Complete ripple netlist.
pub fn configurable_adder_ripple(width: usize) -> Netlist {
    let mut b = NetBuilder::new("softsimd_adder_ripple");
    let io = declare_inputs(&mut b, width);
    let (sums, ovfs) = build_ripple(&mut b, &io);
    b.outputs(&sums);
    b.outputs(&ovfs);
    b.finish()
}

/// Emit carry-select blocks of `block` bits; returns (sum, ovf).
///
/// Each block instantiates the ripple slice twice (block-carry-in 0/1)
/// and muxes sums/ovfs/carry-out — the duplicated chains keep the exact
/// kill/inject behaviour of Fig. 4a inside the block.
pub fn build_carry_select(
    b: &mut NetBuilder,
    io: &AdderIo,
    block: usize,
) -> (Vec<NodeId>, Vec<NodeId>) {
    let width = io.a.len();
    assert_eq!(width % block, 0);
    let sub_gated = b.and2(io.sub, io.add_en);
    let mut sums = vec![];
    let mut ovfs = vec![];
    // Selected carry into the current block (None = constant 0 for block 0).
    let mut blk_cin: Option<NodeId> = None;

    for blk_start in (0..width).step_by(block) {
        // Two ripple chains with assumed carry-in 0 / 1.
        let mut variants: Vec<(Vec<NodeId>, Vec<NodeId>, NodeId)> = vec![];
        for assumed in 0..2u8 {
            let mut sums_v = vec![];
            let mut ovfs_v = vec![];
            let mut carry: Option<NodeId> = if assumed == 0 { None } else { Some(b.one()) };
            for i in blk_start..blk_start + block {
                let c_gated = b.and2(io.c[i], io.add_en);
                let c_eff = b.xor2(c_gated, sub_gated);
                let inject = b.and2(sub_gated, io.l[i]);
                // Propagate-enable from the previous bit (kill at lane MSB).
                let cin = if i == blk_start {
                    match carry {
                        None => inject,
                        Some(cy) => {
                            // Block boundary: the incoming carry must still
                            // respect a lane boundary at bit blk_start-1.
                            if blk_start == 0 {
                                inject
                            } else {
                                let v = b.not(io.m[i - 1]);
                                let kept = b.and2(cy, v);
                                b.or2(kept, inject)
                            }
                        }
                    }
                } else {
                    let cy = carry.expect("mid-block carry");
                    let v = b.not(io.m[i - 1]);
                    let kept = b.and2(cy, v);
                    b.or2(kept, inject)
                };
                let (sum, cout) = b.full_adder(io.a[i], c_eff, cin);
                let ovf = b.xor2(cin, cout);
                sums_v.push(sum);
                ovfs_v.push(ovf);
                carry = Some(cout);
            }
            variants.push((sums_v, ovfs_v, carry.unwrap()));
        }
        let (s0, o0, c0) = variants.swap_remove(0);
        let (s1, o1, c1) = variants.swap_remove(0);
        match blk_cin {
            None => {
                // Block 0: carry-in is exactly 0 — use variant 0 directly.
                sums.extend_from_slice(&s0);
                ovfs.extend_from_slice(&o0);
                blk_cin = Some(c0);
            }
            Some(sel) => {
                for i in 0..block {
                    sums.push(b.mux2(sel, s0[i], s1[i]));
                    ovfs.push(b.mux2(sel, o0[i], o1[i]));
                }
                blk_cin = Some(b.mux2(sel, c0, c1));
            }
        }
    }
    (sums, ovfs)
}

/// Complete carry-select netlist.
pub fn configurable_adder_select(width: usize, block: usize) -> Netlist {
    let mut b = NetBuilder::new("softsimd_adder_select");
    let io = declare_inputs(&mut b, width);
    let (sums, ovfs) = build_carry_select(&mut b, &io, block);
    b.outputs(&sums);
    b.outputs(&ovfs);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::format::SimdFormat;
    use crate::bits::swar::{swar_add, swar_sub};
    use crate::rtl::sim::Simulator;
    use crate::rtl::timing::depth;
    use crate::workload::synth::XorShift64;

    fn drive(
        sim: &mut Simulator,
        net: &Netlist,
        a: u64,
        c: u64,
        add_en: bool,
        sub: bool,
        fmt: SimdFormat,
    ) -> u64 {
        let mut ins = vec![];
        for i in 0..48 {
            ins.push((a >> i) & 1 != 0);
        }
        for i in 0..48 {
            ins.push((c >> i) & 1 != 0);
        }
        ins.push(add_en);
        ins.push(sub);
        let m = fmt.msb_mask();
        let l = fmt.lsb_mask();
        for i in 0..48 {
            ins.push((m >> i) & 1 != 0);
        }
        for i in 0..48 {
            ins.push((l >> i) & 1 != 0);
        }
        sim.set_inputs(&ins);
        sim.eval(net);
        sim.output_u64(net, 0, 48)
    }

    fn check_against_swar(net: &Netlist) {
        let mut sim = Simulator::new(net);
        let mut rng = XorShift64::new(0xADDE5);
        for fmt in SimdFormat::all() {
            for _ in 0..120 {
                let a = rng.word();
                let c = rng.word();
                assert_eq!(
                    drive(&mut sim, net, a, c, true, false, fmt),
                    swar_add(a, c, fmt),
                    "add fmt {fmt}"
                );
                assert_eq!(
                    drive(&mut sim, net, a, c, true, true, fmt),
                    swar_sub(a, c, fmt),
                    "sub fmt {fmt}"
                );
                // add_en = 0: passthrough of a.
                assert_eq!(drive(&mut sim, net, a, c, false, false, fmt), a);
            }
        }
    }

    #[test]
    fn ripple_matches_swar_semantics() {
        check_against_swar(&configurable_adder_ripple(48));
    }

    #[test]
    fn carry_select_matches_swar_semantics() {
        check_against_swar(&configurable_adder_select(48, 4));
    }

    #[test]
    fn select_is_faster_but_bigger() {
        let r = configurable_adder_ripple(48);
        let s = configurable_adder_select(48, 4);
        assert!(depth(&s) < depth(&r) / 2, "{} vs {}", depth(&s), depth(&r));
        assert!(s.logic_cells() > r.logic_cells());
        assert!(s.logic_cells() < 3 * r.logic_cells());
    }

    #[test]
    fn overflow_flag_detects_wrap() {
        // 8-bit lanes: 127 + 1 overflows lane 0; ovf bit at lane MSB (bit 7).
        let net = configurable_adder_ripple(48);
        let mut sim = Simulator::new(&net);
        let fmt = SimdFormat::new(8);
        let a = crate::bits::pack::pack(&[127, 0, 0, 0, 0, 0], fmt);
        let c = crate::bits::pack::pack(&[1, 0, 0, 0, 0, 0], fmt);
        drive(&mut sim, &net, a, c, true, false, fmt);
        let ovf = sim.output_u64(&net, 48, 48);
        assert_ne!(ovf & (1 << 7), 0, "ovf at lane-0 MSB");
    }
}
