//! The single-model coordinator: request intake → dynamic batcher →
//! PE worker pool.
//!
//! Since DESIGN.md §17 the serving machinery itself — batcher lanes,
//! load-aware routing over bounded per-worker queues, the deadline
//! thread, worker fault handling — lives in the fleet front end
//! ([`Fleet`], fleet.rs). The [`Coordinator`] here is the one-model,
//! one-tenant deployment of it, preserved as the simple synchronous
//! API (`submit`/`drain`) the rest of the crate serves through: one
//! pool of `n_pes` PE workers, one unbounded default tenant (admission
//! never sheds), and the same typed [`ServeError`] surface the seed's
//! coordinator grew PR over PR.
//!
//! When the served model carries several precision variants
//! (DESIGN.md §13), every dispatch consults the installed
//! [`GovernorPolicy`] with the live load signals (queued rows + the
//! windowed p99); the chosen variant is stamped on the batch, the
//! batcher's alignment quantum follows it, and the PE worker
//! requantizes the batch's rows ([`Variant::in_shift`]) and bills
//! cycles/energy to the variant it **actually executed** — never to a
//! later decision.
//!
//! [`Variant::in_shift`]: super::model::Variant::in_shift

use std::sync::Arc;
use std::time::Duration;

use super::cost::CostTable;
use super::fleet::{Fleet, FleetConfig, ModelConfig};
use super::governor::{GovernorPolicy, PinnedVariant, SloClass};
use super::metrics::Metrics;
use super::model::CompiledModel;

/// An inference request: rows of quantized activations at the model's
/// reference precision ([`CompiledModel::in_bits`]), whichever variant
/// ends up executing them.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub rows: Vec<Vec<i64>>,
}

/// Its response: per-row logits at the executing variant's final
/// accumulator format, tagged with the variant that produced them so
/// callers can check against the right per-variant oracle, plus the
/// (model, tenant) routing tags the fleet served it under (both 0 for
/// the single-model [`Coordinator`]).
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// The hosted model that served this request.
    pub model: usize,
    /// The tenant class this request was admitted under.
    pub tenant: usize,
    pub logits: Vec<Vec<i64>>,
    /// The precision variant that executed this request's batch.
    pub variant: usize,
}

/// How formed batches are routed to PE workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Rotate over live workers regardless of their backlog.
    RoundRobin,
    /// Send to the live worker with the fewest outstanding rows.
    LeastLoaded,
}

/// Coordinator deployment knobs (also: per-pool knobs of a fleet
/// [`ModelConfig`]). Zero values are *kept* by the builders and
/// rejected with [`ServeError::InvalidConfig`] at
/// [`Coordinator::start`] / [`Fleet::start`] — a nonsense deployment
/// is a typed error for its caller, not a silent clamp or a downstream
/// hang.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Number of PE worker threads (per pool).
    pub n_pes: usize,
    /// Rows the batcher tries to fill before forming a batch.
    pub target_rows: usize,
    /// Bounded depth (in batches) of each worker's queue.
    pub queue_depth: usize,
    /// Straggler flush deadline: a pending sub-target batch is flushed
    /// at most ~this long after its last arrival.
    pub deadline: Duration,
    pub policy: DispatchPolicy,
}

impl ServeConfig {
    pub fn new(n_pes: usize, target_rows: usize) -> ServeConfig {
        ServeConfig {
            n_pes,
            target_rows,
            queue_depth: 2,
            deadline: Duration::from_millis(2),
            policy: DispatchPolicy::LeastLoaded,
        }
    }

    pub fn policy(mut self, policy: DispatchPolicy) -> ServeConfig {
        self.policy = policy;
        self
    }

    pub fn deadline(mut self, deadline: Duration) -> ServeConfig {
        self.deadline = deadline;
        self
    }

    pub fn queue_depth(mut self, depth: usize) -> ServeConfig {
        self.queue_depth = depth;
        self
    }

    /// Reject deployments that cannot serve: zero workers would hang
    /// every dispatch, a zero batch target would never form a batch,
    /// and a zero queue depth is an unbuffered rendezvous no worker
    /// loop services.
    pub fn validate(&self) -> Result<(), ServeError> {
        if self.n_pes == 0 {
            return Err(ServeError::InvalidConfig {
                what: "n_pes == 0 (need at least one PE worker)",
            });
        }
        if self.target_rows == 0 {
            return Err(ServeError::InvalidConfig {
                what: "target_rows == 0 (batches would never form)",
            });
        }
        if self.queue_depth == 0 {
            return Err(ServeError::InvalidConfig {
                what: "queue_depth == 0 (worker queues need capacity)",
            });
        }
        Ok(())
    }
}

/// Serving failures surfaced to the caller (instead of the seed's
/// `expect("worker alive")` panics).
#[derive(Debug)]
pub enum ServeError {
    /// The deployment description is unservable (zero workers, zero
    /// batch target, zero queue depth, no models, no tenants); nothing
    /// was spawned.
    InvalidConfig { what: &'static str },
    /// The request named a model id the fleet does not host.
    UnknownModel { model: usize },
    /// The request named a tenant id the fleet has no class for.
    UnknownTenant { tenant: usize },
    /// The request doesn't fit the model (wrong row width, no rows, or
    /// out-of-range raw values); nothing was enqueued. Rejecting at
    /// submit keeps a malformed request from panicking a PE worker.
    InvalidRequest { id: u64, reason: String },
    /// Admission control refused the request: the certified drain time
    /// of the tenant's already-queued rows exceeds its SLO class's
    /// budget (DESIGN.md §17). The request was never enqueued — load
    /// shedding is a typed refusal, not a silent drop.
    Shed { tenant: usize, reason: String },
    /// Every PE worker is dead; the offending rows were restored to the
    /// batcher, not dropped. `recovered` carries any responses that
    /// were still collected (empty on the submit path).
    NoLiveWorkers { recovered: Vec<Response> },
    /// One or more workers died holding dispatched work; `recovered`
    /// carries every response the remaining workers still produced.
    WorkerLost {
        workers: Vec<usize>,
        lost_rows: usize,
        recovered: Vec<Response>,
    },
    /// A shared lock was poisoned by a panicking holder. Submit-path
    /// callers get this instead of a propagated panic; `recovered`
    /// carries any responses `drain` still collected. Observability
    /// and teardown paths (`pending_rows`, `kill_worker`, `shutdown`,
    /// the deadline tick) recover the lock instead — they must make
    /// progress even after a panic elsewhere.
    LockPoisoned {
        what: &'static str,
        recovered: Vec<Response>,
    },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::InvalidConfig { what } => {
                write!(f, "invalid serve config: {what}")
            }
            ServeError::UnknownModel { model } => {
                write!(f, "unknown model id {model}")
            }
            ServeError::UnknownTenant { tenant } => {
                write!(f, "unknown tenant id {tenant}")
            }
            ServeError::InvalidRequest { id, reason } => {
                write!(f, "invalid request {id}: {reason}")
            }
            ServeError::Shed { tenant, reason } => {
                write!(f, "request shed for tenant {tenant}: {reason}")
            }
            ServeError::NoLiveWorkers { recovered } => write!(
                f,
                "no live PE workers ({} responses recovered)",
                recovered.len()
            ),
            ServeError::WorkerLost { workers, lost_rows, recovered } => write!(
                f,
                "PE worker(s) {workers:?} died holding {lost_rows} dispatched \
                 rows ({} responses recovered)",
                recovered.len()
            ),
            ServeError::LockPoisoned { what, recovered } => write!(
                f,
                "{what} lock poisoned by a panicking holder ({} responses \
                 recovered)",
                recovered.len()
            ),
        }
    }
}

impl std::error::Error for ServeError {}

/// The running coordinator: a one-model, one-tenant [`Fleet`].
pub struct Coordinator {
    pub(crate) fleet: Fleet,
    pub metrics: Arc<Metrics>,
}

impl Coordinator {
    /// Spawn `cfg.n_pes` worker PEs serving the shared compiled model
    /// at its reference variant, with no precision governor (a
    /// multi-variant model serves variant 0 until a policy is installed
    /// via [`Coordinator::start_with_policy`]). Plans are compiled by
    /// [`CompiledModel::compile`], exactly once, before this call;
    /// workers only clone the `Arc`. Fails with
    /// [`ServeError::InvalidConfig`] on an unservable `cfg`.
    pub fn start(
        model: Arc<CompiledModel>,
        cfg: ServeConfig,
        cost: CostTable,
    ) -> Result<Coordinator, ServeError> {
        Coordinator::start_with_policy(model, cfg, cost, Box::new(PinnedVariant(0)))
    }

    /// As [`Coordinator::start`], with a precision-governor policy
    /// consulted at every batch dispatch (DESIGN.md §13).
    pub fn start_with_policy(
        model: Arc<CompiledModel>,
        cfg: ServeConfig,
        cost: CostTable,
        policy: Box<dyn GovernorPolicy>,
    ) -> Result<Coordinator, ServeError> {
        cfg.validate()?;
        let fleet = Fleet::start(
            FleetConfig::new()
                .model(ModelConfig::new(model, cost, cfg))
                .tenant(SloClass::unbounded("default")),
        )?;
        fleet.install_policy(0, 0, policy)?;
        let metrics = fleet.model_metrics(0);
        Ok(Coordinator { fleet, metrics })
    }

    /// The variant the governor chose at the most recent dispatch
    /// (observability; per-batch billing follows each batch's own tag).
    pub fn active_variant(&self) -> usize {
        self.fleet.active_variant(0, 0)
    }

    /// Submit a request (may trigger a batch dispatch). Shape and range
    /// are validated at admission so a malformed request is an error
    /// for its sender, never a panic inside a PE worker.
    pub fn submit(&mut self, req: Request) -> Result<(), ServeError> {
        self.fleet.submit(0, 0, req)
    }

    /// Rows batched but not yet dispatched (waiting on the deadline).
    /// Observability must survive a poisoned lock.
    pub fn pending_rows(&self) -> usize {
        self.fleet.pending_rows()
    }

    /// Fault injection / rolling restart: stop worker `idx` after it
    /// finishes its queued work. Routing avoids it immediately; its
    /// in-queue work still completes and is collected by `drain`.
    pub fn kill_worker(&mut self, idx: usize) {
        self.fleet.kill_worker(0, 0, idx);
    }

    /// Rolling-restart companion of [`kill_worker`]: respawn a dead
    /// PE in its slot — fresh thread, fresh bounded queue, same
    /// outstanding-work counters — and re-arm routing to it. Returns
    /// `false` (and does nothing) for an out-of-range slot or a worker
    /// that is still alive; a killed worker is first joined, so any
    /// work still in its old queue completes and is collected before
    /// the replacement takes over. Without this, every
    /// [`kill_worker`] permanently shrank serving capacity.
    ///
    /// [`kill_worker`]: Coordinator::kill_worker
    pub fn revive_worker(&mut self, idx: usize) -> bool {
        self.fleet.revive_worker(0, 0, idx)
    }

    /// Flush stragglers and wait for every response. On failure the
    /// error still carries whatever responses could be collected —
    /// completed work is never stranded behind an error.
    pub fn drain(&mut self) -> Result<Vec<Response>, ServeError> {
        self.fleet.drain()
    }

    /// Stop the deadline thread and workers, then join them.
    pub fn shutdown(self) {
        self.fleet.shutdown()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::exec::mlp_forward_row;
    use crate::nn::weights::QuantLayer;
    use crate::testutil::{flat_cost as tiny_cost, random_dense_stack_uniform};
    use crate::workload::synth::XorShift64;
    use std::sync::atomic::Ordering;

    fn layers(rng: &mut XorShift64) -> Vec<QuantLayer> {
        random_dense_stack_uniform(rng, &[8, 5, 3], 8)
    }

    #[test]
    fn coordinator_round_trip_matches_reference() {
        let mut rng = XorShift64::new(0xC00D);
        let ls = layers(&mut rng);
        let model = CompiledModel::compile(ls.clone(), 8, 16).unwrap();
        let mut coord =
            Coordinator::start(model, ServeConfig::new(2, 6), tiny_cost()).unwrap();
        let reqs: Vec<Request> = (0..9u64)
            .map(|id| Request {
                id,
                rows: (0..(1 + (id as usize % 3)))
                    .map(|_| (0..8).map(|_| rng.q_raw(8)).collect())
                    .collect(),
            })
            .collect();
        let expected: Vec<Vec<Vec<i64>>> = reqs
            .iter()
            .map(|r| r.rows.iter().map(|row| mlp_forward_row(row, &ls, 8, 16)).collect())
            .collect();
        for r in reqs {
            coord.submit(r).unwrap();
        }
        let responses = coord.drain().unwrap();
        assert_eq!(responses.len(), 9);
        for resp in &responses {
            assert_eq!(resp.logits, expected[resp.id as usize], "request {}", resp.id);
            assert_eq!((resp.model, resp.tenant), (0, 0));
        }
        assert!(coord.metrics.subword_mults.load(Ordering::Relaxed) > 0);
        coord.shutdown();
    }

    #[test]
    fn mixed_precision_model_serves_bit_exactly() {
        use crate::nn::exec::mlp_forward_row_mixed;
        use crate::nn::weights::LayerPrecision;
        let mut rng = XorShift64::new(0x417C0DE);
        let ls = layers(&mut rng);
        // 4-bit first layer, 8-bit second — with a direct 8→8 bypass
        // boundary; requests arrive quantized at 4 bits.
        let sched = vec![LayerPrecision::new(4, 8), LayerPrecision::new(8, 16)];
        let model = CompiledModel::compile_scheduled(ls.clone(), sched.clone()).unwrap();
        let mut coord =
            Coordinator::start(model, ServeConfig::new(2, 6), tiny_cost()).unwrap();
        let reqs: Vec<Request> = (0..7u64)
            .map(|id| Request {
                id,
                rows: vec![(0..8).map(|_| rng.q_raw(4)).collect()],
            })
            .collect();
        for r in &reqs {
            coord.submit(r.clone()).unwrap();
        }
        let responses = coord.drain().unwrap();
        assert_eq!(responses.len(), 7);
        for resp in &responses {
            let want = mlp_forward_row_mixed(&reqs[resp.id as usize].rows[0], &ls, &sched);
            assert_eq!(resp.logits[0], want, "request {}", resp.id);
        }
        // An out-of-range 8-bit value is invalid against a 4-bit input
        // layer: the submit-time Q-range check tracks the schedule.
        let err = coord
            .submit(Request { id: 99, rows: vec![vec![100, 0, 0, 0, 0, 0, 0, 0]] })
            .expect_err("out of 4-bit range");
        assert!(err.to_string().contains("outside Q range"), "{err}");
        coord.shutdown();
    }

    #[test]
    fn batching_groups_requests() {
        let mut rng = XorShift64::new(0xBA7);
        let ls = layers(&mut rng);
        let model = CompiledModel::compile(ls, 8, 16).unwrap();
        // A generous deadline so the batcher, not the deadline thread,
        // forms the batches in this test.
        let cfg = ServeConfig::new(1, 12).deadline(Duration::from_secs(5));
        let mut coord = Coordinator::start(model, cfg, tiny_cost()).unwrap();
        for id in 0..12u64 {
            coord
                .submit(Request {
                    id,
                    rows: vec![(0..8).map(|_| rng.q_raw(8)).collect()],
                })
                .unwrap();
        }
        let responses = coord.drain().unwrap();
        assert_eq!(responses.len(), 12);
        let batches = coord.metrics.batches.load(Ordering::Relaxed);
        assert!(batches <= 2, "expected ≤2 batches, got {batches}");
        coord.shutdown();
    }

    #[test]
    fn zero_knobs_are_typed_config_errors() {
        let mut rng = XorShift64::new(0x2E20);
        let ls = layers(&mut rng);
        let model = CompiledModel::compile(ls, 8, 16).unwrap();
        for (cfg, needle) in [
            (ServeConfig::new(0, 6), "n_pes"),
            (ServeConfig::new(2, 0), "target_rows"),
            (ServeConfig::new(2, 6).queue_depth(0), "queue_depth"),
        ] {
            match Coordinator::start(Arc::clone(&model), cfg, tiny_cost()) {
                Err(ServeError::InvalidConfig { what }) => {
                    assert!(what.contains(needle), "{what} should name {needle}");
                }
                Ok(_) => panic!("zero {needle} must not start"),
                Err(other) => panic!("expected InvalidConfig, got {other}"),
            }
        }
        // The non-zero baseline still starts.
        let coord =
            Coordinator::start(model, ServeConfig::new(2, 6), tiny_cost()).unwrap();
        coord.shutdown();
    }

    #[test]
    fn poisoned_batcher_degrades_to_typed_errors_not_panics() {
        let mut rng = XorShift64::new(0xDEAD10);
        let ls = layers(&mut rng);
        let model = CompiledModel::compile(ls, 8, 16).unwrap();
        let cfg = ServeConfig::new(1, 4).deadline(Duration::from_secs(5));
        let mut coord = Coordinator::start(model, cfg, tiny_cost()).unwrap();
        // Poison the batcher lock of the wrapper's single lane: a
        // thread panics while holding it.
        let shared = Arc::clone(&coord.fleet.shared);
        let _ = std::thread::spawn(move || {
            let _guard = shared.models[0].pools[0].lanes[0].batcher.lock().unwrap();
            panic!("deliberate poison (test)");
        })
        .join();
        // Submits are refused with a typed error, not a propagated
        // panic…
        let req = Request { id: 1, rows: vec![vec![0i64; 8]] };
        match coord.submit(req) {
            Err(ServeError::LockPoisoned { what: "batcher", .. }) => {}
            other => panic!("expected LockPoisoned, got {other:?}"),
        }
        // …observability recovers the lock…
        assert_eq!(coord.pending_rows(), 0);
        // …drain surfaces the same condition, with whatever completed…
        match coord.drain() {
            Err(ServeError::LockPoisoned { what: "batcher", recovered }) => {
                assert!(recovered.is_empty());
            }
            other => panic!("expected LockPoisoned from drain, got {other:?}"),
        }
        // …and teardown still joins every thread.
        coord.shutdown();
    }

    #[test]
    fn round_robin_rotates_and_least_loaded_prefers_idle() {
        let mut rng = XorShift64::new(0xD15);
        let ls = layers(&mut rng);
        let model = CompiledModel::compile(ls, 8, 16).unwrap();
        for policy in [DispatchPolicy::RoundRobin, DispatchPolicy::LeastLoaded] {
            let cfg = ServeConfig::new(3, 1).policy(policy);
            let mut coord =
                Coordinator::start(Arc::clone(&model), cfg, tiny_cost()).unwrap();
            for id in 0..30u64 {
                coord
                    .submit(Request {
                        id,
                        rows: vec![(0..8).map(|_| rng.q_raw(8)).collect()],
                    })
                    .unwrap();
            }
            let responses = coord.drain().unwrap();
            assert_eq!(responses.len(), 30, "{policy:?}");
            coord.shutdown();
        }
    }
}
