//! Hard SIMD pipeline cost model.
//!
//! One pipeline stage: operand registers A/B (48b each) feed the
//! combinational SIMD multiplier bank; the packed product lands in OUT
//! (48b). One multiplication of a full word per cycle, whatever the
//! sub-word width — flexibility is paid in silicon, not cycles.

use crate::bits::format::SimdFormat;
use crate::energy::model::{PipelineArea, RegBank, SynthBlock};
use crate::energy::tech::GlitchClass;
use crate::rtl::multiplier::{divisible_array, drive_bank};
use crate::workload::synth::XorShift64;

/// The flexible baseline's format set.
pub const HARD_FLEX: &[u32] = &[4, 6, 8, 12, 16];
/// The lean baseline's format set.
pub const HARD_TWO: &[u32] = &[8, 16];

/// A synthesized Hard SIMD pipeline.
pub struct HardSimdPipeline {
    pub name: String,
    pub fmts: Vec<u32>,
    pub mhz: f64,
    pub bank: SynthBlock,
    pub regs: RegBank,
    prev_a: u64,
    prev_b: u64,
    prev_out: u64,
}

impl HardSimdPipeline {
    pub fn new(fmts: &[u32], mhz: f64) -> Self {
        // Cost carrier: the shared divisible array (see rtl::multiplier).
        let bank = SynthBlock::new(divisible_array(fmts), GlitchClass::MultiplierArray);
        HardSimdPipeline {
            name: format!("Hard SIMD {fmts:?}"),
            fmts: fmts.to_vec(),
            mhz,
            bank,
            // A(48) + B(48) + OUT(48) + fmt-config(8).
            regs: RegBank { bits: 48 * 3 + 8 },
            prev_a: 0,
            prev_b: 0,
            prev_out: 0,
        }
    }

    /// Smallest supported sub-word width fitting both operand widths —
    /// the allocation rule that produces the Fig. 9 discontinuities.
    pub fn fit_width(&self, x_bits: u32, y_bits: u32) -> Option<u32> {
        let need = x_bits.max(y_bits);
        self.fmts.iter().copied().filter(|&b| b >= need).min()
    }

    /// Effective array activity at sub-word width `b`.
    ///
    /// The divisible array's partition gating confines *useful* partial
    /// products to a fraction `frac(b) = b/16` of each 16-bit grid, but
    /// gating in a shared array is imperfect — gating signals race the
    /// data and reconvergent paths glitch through — so a share of the
    /// nominally-idle region still switches:
    /// `eff = frac + λ·(1 − frac)`, with the glitch-through share λ
    /// growing with the number of supported partitions (each extra
    /// boundary adds gating reconvergence): `λ = 0.25·(#formats − 1)`, capped at 1.
    /// Zero-delay simulation cannot see either effect; calibration note
    /// in DESIGN.md §6.
    fn activity(&self, b: u32) -> f64 {
        let frac = b as f64 / 16.0;
        let lambda = (0.25 * (self.fmts.len() as f64 - 1.0)).min(1.0);
        frac + lambda * (1.0 - frac)
    }

    pub fn area(&self) -> PipelineArea {
        PipelineArea {
            name: self.name.clone(),
            mhz: self.mhz,
            stage1_um2: self.bank.area_um2(self.mhz),
            stage2_um2: 0.0,
            regs_um2: self.regs.area_um2(self.mhz),
        }
    }

    /// Run `n_words` packed multiplications at sub-word width `b` with
    /// operands carrying `x_bits`/`y_bits` of information (Q1
    /// value-aligned inside the lane); returns total pJ (dynamic +
    /// registers + leakage).
    pub fn word_mult_energy_pj(
        &mut self,
        b: u32,
        x_bits: u32,
        y_bits: u32,
        n_words: usize,
        rng: &mut XorShift64,
    ) -> f64 {
        let fmt = SimdFormat::new(b);
        self.bank.sim.reset_counters();
        let mut reg_pj = 0.0;
        for _ in 0..n_words {
            // Hard SIMD lanes are integer lanes (NEON/AVX-style): narrow
            // operands sit right-aligned and *sign-extend* through the
            // lane — unlike Soft SIMD's Q1 value alignment. The sign
            // copies are data-dependent, so they switch the array.
            let xl: Vec<i64> = (0..fmt.lanes()).map(|_| rng.q_raw(x_bits)).collect();
            let ml: Vec<i64> = (0..fmt.lanes()).map(|_| rng.q_raw(y_bits)).collect();
            let a = crate::bits::pack::pack(&xl, fmt);
            let m = crate::bits::pack::pack(&ml, fmt);
            let out = drive_bank(&mut self.bank.sim, &self.bank.net, &self.fmts, a, m, fmt);
            let written = (a ^ self.prev_a).count_ones()
                + (m ^ self.prev_b).count_ones()
                + (out ^ self.prev_out).count_ones();
            reg_pj += self.regs.cycle_pj(written);
            self.prev_a = a;
            self.prev_b = m;
            self.prev_out = out;
        }
        let dyn_pj = self.bank.take_energy_pj(self.mhz) * self.activity(b);
        let leak_pj = (self.bank.leak_pj_per_cycle(self.mhz)
            + self.regs.leak_pj_per_cycle(self.mhz))
            * n_words as f64;
        dyn_pj + reg_pj + leak_pj
    }

    /// Energy per *sub-word* multiplication at operand widths
    /// (x_bits × y_bits); `None` if unsupported. Uses the fit rule +
    /// lane amortization.
    pub fn subword_mult_energy_pj(
        &mut self,
        x_bits: u32,
        y_bits: u32,
        n_words: usize,
        rng: &mut XorShift64,
    ) -> Option<f64> {
        let b = self.fit_width(x_bits, y_bits)?;
        let fmt = SimdFormat::new(b);
        let total = self.word_mult_energy_pj(b, x_bits, y_bits, n_words, rng);
        Some(total / (n_words as f64 * fmt.lanes() as f64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_width_rule() {
        let two = HardSimdPipeline::new(HARD_TWO, 200.0);
        assert_eq!(two.fit_width(8, 8), Some(8));
        assert_eq!(two.fit_width(9, 4), Some(16)); // the Fig. 9 jump
        assert_eq!(two.fit_width(17, 8), None);
        let flex = HardSimdPipeline::new(HARD_FLEX, 200.0);
        assert_eq!(flex.fit_width(9, 4), Some(12));
        assert_eq!(flex.fit_width(5, 5), Some(6));
    }

    #[test]
    fn flexible_bank_larger_area() {
        let two = HardSimdPipeline::new(HARD_TWO, 200.0);
        let flex = HardSimdPipeline::new(HARD_FLEX, 200.0);
        assert!(flex.area().total() > 1.15 * two.area().total());
    }

    #[test]
    fn wider_subwords_cost_more_energy() {
        let mut p = HardSimdPipeline::new(HARD_TWO, 1000.0);
        let mut rng = XorShift64::new(0xE7E7);
        let e8 = p.subword_mult_energy_pj(8, 8, 64, &mut rng).unwrap();
        let e16 = p.subword_mult_energy_pj(16, 16, 64, &mut rng).unwrap();
        assert!(e16 > 1.5 * e8, "e8={e8} e16={e16}");
    }

    #[test]
    fn nine_bit_jump_on_two_format_pipeline() {
        let mut p = HardSimdPipeline::new(HARD_TWO, 1000.0);
        let mut rng = XorShift64::new(0x9B17);
        let e8 = p.subword_mult_energy_pj(8, 8, 64, &mut rng).unwrap();
        let e9 = p.subword_mult_energy_pj(9, 8, 64, &mut rng).unwrap();
        assert!(e9 > 1.05 * e8, "discontinuity missing: e8={e8} e9={e9}");
    }
}
