//! Runtime — loading and executing the AOT JAX/Pallas artifacts via
//! PJRT, plus the cross-language golden-vector checker.
//!
//! Python authors and lowers the computations at build time
//! (`make artifacts`); this module is the only place the compiled HLO is
//! touched at run time. Interchange is HLO *text* (see
//! `python/compile/aot.py` for why not serialized protos).

pub mod golden;
pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use manifest::Manifest;
pub use pjrt::Engine;
