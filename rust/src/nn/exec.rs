//! Quantized MLP forward passes.
//!
//! Layer semantics (DESIGN.md §4/§10, mirrored by
//! `python/compile/kernels/ref.py::layer_ref`): products at the layer's
//! `in_bits` via the Soft SIMD shift-add multiply, widened (`<< acc−in`)
//! to the layer's accumulator format — a Stage-2 conversion — summed
//! with wrapping `acc_bits` adds; hidden layers apply ReLU then convert
//! through the Stage-2 crossbar chain into the *next* layer's `in_bits`.
//! Every layer may declare its own format pair ([`LayerPrecision`]);
//! [`mlp_forward_row_mixed`] is the scalar oracle the packed serving
//! engine must match bit-exactly at every layer boundary.

use crate::bits::fixed::sign_extend;
use crate::bits::format::SimdFormat;
use crate::pipeline::stage1::{mul_scalar_plan, mul_scalar};
use crate::pipeline::stage2::{conversion_chain, convert_subword};

use super::conv::{conv_forward_row, LayerOp};
use super::weights::{uniform_schedule, LayerPrecision, QuantLayer};

/// The inter-layer activation unit: ReLU at the producing layer's
/// accumulator format, then the Stage-2 conversion chain into the
/// consuming layer's activation format. Applying the chain hop-by-hop
/// (not one composed shift) keeps this the exact scalar mirror of the
/// engine's `repack_stream` boundary (DESIGN.md §10).
pub fn requantize_activation(v: i64, from_acc: SimdFormat, to_in: SimdFormat) -> i64 {
    let mut x = v.max(0);
    for (f, t) in conversion_chain(from_acc, to_in) {
        x = convert_subword(x, f, t);
    }
    x
}

/// One dense layer's pre-activation accumulators for one input row
/// (the shared inner step of every scalar oracle): products at
/// `p.in_bits` via the Soft SIMD shift-add multiply, widened
/// `<< (acc−in)`, summed with wrapping `acc_bits` adds.
pub fn dense_layer_row(h: &[i64], layer: &QuantLayer, p: LayerPrecision) -> Vec<i64> {
    assert_eq!(h.len(), layer.k, "dense input width");
    assert!(p.acc_bits >= p.in_bits, "dense precision {p}");
    let mask = (1u64 << p.acc_bits) - 1;
    let mut out = vec![0i64; layer.n];
    for j in 0..layer.n {
        let mut acc = 0i64;
        for i in 0..layer.k {
            let prod = mul_scalar(h[i], layer.w_raw[i][j], p.in_bits, layer.bits);
            acc += prod << (p.acc_bits - p.in_bits);
        }
        out[j] = sign_extend(acc as u64 & mask, p.acc_bits);
    }
    out
}

/// Forward one input row through a mixed-precision layer stack: layer
/// `li` consumes `schedule[li].in_bits` activations and produces
/// `schedule[li].acc_bits` accumulators. Returns the final layer's
/// pre-activation accumulators (`Q1.(acc_bits-1)` raws).
pub fn mlp_forward_row_mixed(
    x_q: &[i64],
    layers: &[QuantLayer],
    schedule: &[LayerPrecision],
) -> Vec<i64> {
    assert!(!layers.is_empty(), "empty layer stack");
    assert_eq!(layers.len(), schedule.len(), "one precision per layer");
    let mut h: Vec<i64> = x_q.to_vec();
    for (li, (layer, p)) in layers.iter().zip(schedule).enumerate() {
        assert_eq!(h.len(), layer.k, "layer {li} input width");
        let out = dense_layer_row(&h, layer, *p);
        if li + 1 < layers.len() {
            let next_in = schedule[li + 1].in_fmt();
            h = out
                .iter()
                .map(|&v| requantize_activation(v, p.acc_fmt(), next_in))
                .collect();
        } else {
            return out;
        }
    }
    unreachable!("the loop returns on the last layer")
}

/// Forward one input row through an interleaved conv + dense stack —
/// the scalar oracle for the conv-capable serving engine (DESIGN.md
/// §12). Layer `li` consumes its flattened input features at
/// `schedule[li].in_bits` and produces flattened pre-activation
/// accumulators at `schedule[li].acc_bits`; hidden layers apply ReLU
/// then the Stage-2 conversion chain into the next layer's activation
/// format, identically for conv and dense.
pub fn stack_forward_row(
    x_q: &[i64],
    ops: &[LayerOp],
    schedule: &[LayerPrecision],
) -> Vec<i64> {
    assert!(!ops.is_empty(), "empty layer stack");
    assert_eq!(ops.len(), schedule.len(), "one precision per layer");
    let mut h: Vec<i64> = x_q.to_vec();
    for (li, (op, p)) in ops.iter().zip(schedule).enumerate() {
        assert_eq!(h.len(), op.in_len(), "layer {li} input length");
        let out = match op {
            LayerOp::Dense(layer) => dense_layer_row(&h, layer, *p),
            LayerOp::Conv(layer) => conv_forward_row(&h, layer, *p),
        };
        if li + 1 < ops.len() {
            let next_in = schedule[li + 1].in_fmt();
            h = out
                .iter()
                .map(|&v| requantize_activation(v, p.acc_fmt(), next_in))
                .collect();
        } else {
            return out;
        }
    }
    unreachable!("the loop returns on the last layer")
}

/// Forward one input row through all layers at one uniform format pair;
/// returns the final pre-activation accumulators (`Q1.(acc_bits-1)`
/// raws). Shorthand for [`mlp_forward_row_mixed`] with a uniform
/// schedule.
pub fn mlp_forward_row(x_q: &[i64], layers: &[QuantLayer], in_bits: u32, acc_bits: u32) -> Vec<i64> {
    mlp_forward_row_mixed(x_q, layers, &uniform_schedule(in_bits, acc_bits, layers.len()))
}

/// Batched forward; `x` is row-major `[batch][k]`.
pub fn mlp_forward_batch(
    x: &[Vec<i64>],
    layers: &[QuantLayer],
    in_bits: u32,
    acc_bits: u32,
) -> Vec<Vec<i64>> {
    x.iter()
        .map(|row| mlp_forward_row(row, layers, in_bits, acc_bits))
        .collect()
}

/// Forward with *precomputed plans* (avoids re-encoding CSD per call;
/// the scalar mirror of the packed serving path).
pub fn mlp_forward_row_planned(
    x_q: &[i64],
    layers: &[QuantLayer],
    plans: &[Vec<Vec<crate::csd::schedule::MulPlan>>],
    in_bits: u32,
    acc_bits: u32,
) -> Vec<i64> {
    assert!(!layers.is_empty(), "empty layer stack");
    let mut h: Vec<i64> = x_q.to_vec();
    for (li, layer) in layers.iter().enumerate() {
        let mut out = vec![0i64; layer.n];
        for j in 0..layer.n {
            let mut acc = 0i64;
            for i in 0..layer.k {
                let p = mul_scalar_plan(h[i], &plans[li][i][j], in_bits);
                acc += p << (acc_bits - in_bits);
            }
            out[j] = sign_extend(acc as u64 & ((1u64 << acc_bits) - 1), acc_bits);
        }
        if li + 1 < layers.len() {
            h = out
                .iter()
                .map(|&v| v.max(0) >> (acc_bits - in_bits))
                .collect();
        } else {
            return out;
        }
    }
    h
}

/// Precompute all layer plans for [`mlp_forward_row_planned`]. This is
/// the expensive, quantization-dependent compilation step; the serving
/// stack runs it exactly once per model inside
/// [`crate::coordinator::CompiledModel::compile`] and shares the result
/// across PE workers.
pub fn precompute_plans(
    layers: &[QuantLayer],
) -> Vec<Vec<Vec<crate::csd::schedule::MulPlan>>> {
    layers.iter().map(QuantLayer::plans).collect()
}

/// Argmax over the first `classes` outputs (logit decision; first-max
/// wins ties, matching `numpy.argmax`).
pub fn argmax_class(logits: &[i64], classes: usize) -> usize {
    let mut best = 0usize;
    for i in 1..classes.min(logits.len()) {
        if logits[i] > logits[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_layers() -> Vec<QuantLayer> {
        // 2 → 2 → 2 with simple weights.
        vec![
            QuantLayer::new(vec![vec![64, -64], vec![32, 32]], 8), // 0.5/-0.5; 0.25/0.25
            QuantLayer::new(vec![vec![127, 0], vec![0, 127]], 8),
        ]
    }

    #[test]
    fn forward_matches_hand_computation() {
        let layers = tiny_layers();
        let x = vec![64i64, 64]; // 0.5, 0.5
        // Layer 0: n0 = 0.5·0.5 + 0.5·0.25 = 0.375 → raw16 (64·64>>7=32,
        // 64·32>>7=16 → (32+16)<<8 = 12288). n1 = −0.25+0.125 → ((−32)+16)<<8 = −4096.
        // ReLU+requant: h = [12288>>8, 0] = [48, 0].
        // Layer 1 (≈identity·0.992): n0 = mul(48,127)<<8, n1 = 0.
        let out = mlp_forward_row(&x, &layers, 8, 16);
        let p = mul_scalar(48, 127, 8, 8);
        assert_eq!(out, vec![p << 8, 0]);
    }

    #[test]
    fn planned_path_matches_unplanned() {
        let layers = tiny_layers();
        let plans = precompute_plans(&layers);
        for x0 in [-128i64, -5, 0, 99, 127] {
            for x1 in [-77i64, 0, 127] {
                let x = vec![x0, x1];
                assert_eq!(
                    mlp_forward_row(&x, &layers, 8, 16),
                    mlp_forward_row_planned(&x, &layers, &plans, 8, 16)
                );
            }
        }
    }

    #[test]
    fn argmax_first_wins_ties_deterministically() {
        assert_eq!(argmax_class(&[5, 5, 1], 3), 0);
        assert_eq!(argmax_class(&[1, 9, 9], 3), 1);
    }

    #[test]
    fn mixed_oracle_with_uniform_schedule_matches_uniform_path() {
        let layers = tiny_layers();
        let sched = uniform_schedule(8, 16, layers.len());
        for x0 in [-128i64, -5, 0, 99, 127] {
            for x1 in [-77i64, 0, 127] {
                let x = vec![x0, x1];
                assert_eq!(
                    mlp_forward_row(&x, &layers, 8, 16),
                    mlp_forward_row_mixed(&x, &layers, &sched)
                );
            }
        }
    }

    #[test]
    fn requantize_activation_relu_then_chained_conversion() {
        let f16 = SimdFormat::new(16);
        let f8 = SimdFormat::new(8);
        let f4 = SimdFormat::new(4);
        // Negative accumulators clip to zero before any conversion.
        assert_eq!(requantize_activation(-12345, f16, f8), 0);
        // Direct narrowing hop: value-aligned truncation.
        assert_eq!(requantize_activation(0x1234, f16, f8), 0x12);
        // Two-hop 16→4 (via 8) composes to the direct >>12 truncation.
        assert_eq!(requantize_activation(0x7FFF, f16, f4), 7);
        // Widening appends fractional zeros exactly.
        assert_eq!(requantize_activation(3, f4, f8), 3 << 4);
    }

    #[test]
    fn mixed_oracle_respects_per_layer_lane_width() {
        // A widening 4→8 schedule: layer 0 consumes 4-bit activations
        // (products at 4-bit lanes), layer 1 consumes 8-bit ones.
        let layers = vec![
            QuantLayer::new(vec![vec![4], vec![2]], 4), // 0.5, 0.25 @ Q1.3
            QuantLayer::new(vec![vec![64]], 8),         // 0.5 @ Q1.7
        ];
        let sched = vec![LayerPrecision::new(4, 8), LayerPrecision::new(8, 16)];
        let x = vec![4i64, 4]; // 0.5, 0.5 @ Q1.3
        // Layer 0: mul(4,4,@4b)=2, mul(4,2,@4b)=1 → (2+1)<<4 = 48 @Q1.7.
        // Boundary 8→8: identity. Layer 1: mul(48,64,@8b)=24 → 24<<8.
        let out = mlp_forward_row_mixed(&x, &layers, &sched);
        assert_eq!(out, vec![24 << 8]);
    }

    #[test]
    #[should_panic(expected = "empty layer stack")]
    fn forward_rejects_empty_layer_stack() {
        let _ = mlp_forward_row(&[1, 2], &[], 8, 16);
    }

    #[test]
    fn stack_oracle_on_dense_ops_matches_mlp_oracle() {
        let layers = tiny_layers();
        let ops: Vec<crate::nn::conv::LayerOp> = layers
            .iter()
            .cloned()
            .map(crate::nn::conv::LayerOp::Dense)
            .collect();
        let sched = uniform_schedule(8, 16, layers.len());
        for x0 in [-128i64, 0, 99] {
            let x = vec![x0, 64];
            assert_eq!(
                stack_forward_row(&x, &ops, &sched),
                mlp_forward_row_mixed(&x, &layers, &sched)
            );
        }
    }

    #[test]
    fn stack_oracle_runs_conv_then_dense() {
        use crate::nn::conv::{ConvLayer, ConvShape, LayerOp};
        // conv 1x2x2 → 1ch 1x1 (2x2 kernel, no pad) then dense 1 → 1:
        // the conv output feeds the dense head through ReLU + requant.
        let shape =
            ConvShape { cin: 1, h: 2, w: 2, cout: 1, kh: 2, kw: 2, stride: 1, pad: 0 };
        let conv = ConvLayer::new(
            QuantLayer::new(vec![vec![64], vec![0], vec![0], vec![0]], 8),
            shape,
        )
        .unwrap();
        let ops = vec![
            LayerOp::Conv(conv),
            LayerOp::Dense(QuantLayer::new(vec![vec![127]], 8)),
        ];
        let sched = vec![LayerPrecision::new(8, 16), LayerPrecision::new(8, 16)];
        let x = vec![100i64, 1, 2, 3];
        // Conv: mul(100, 64) << 8 = 50 << 8. Boundary 16→8: ReLU then
        // truncate → 50. Dense: mul(50, 127) << 8.
        let out = stack_forward_row(&x, &ops, &sched);
        assert_eq!(out, vec![mul_scalar(50, 127, 8, 8) << 8]);
    }
}
