//! Static timing: levelized depth of a netlist (FO4-normalized levels).
//!
//! Like real STA, runtime configuration inputs (`V_x`, format selects)
//! are treated as unknowns — the reported depth is the structural worst
//! case over all configurations.

use super::gate::{Netlist, NO_NET};

/// Longest input→output path in levels (see [`super::gate::CellKind::levels`]).
pub fn depth(net: &Netlist) -> u32 {
    let mut lvl = vec![0u32; net.cells.len()];
    for (i, cell) in net.cells.iter().enumerate() {
        let mut input_lvl = 0;
        for op in [cell.a, cell.b, cell.sel] {
            if op != NO_NET {
                input_lvl = input_lvl.max(lvl[op as usize]);
            }
        }
        lvl[i] = input_lvl + cell.kind.levels();
    }
    net.outputs
        .iter()
        .map(|&o| lvl[o as usize])
        .max()
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtl::build::NetBuilder;

    #[test]
    fn chain_depth_is_linear() {
        let mut b = NetBuilder::new("chain");
        let ins = b.inputs(9);
        let mut acc = ins[0];
        for &i in &ins[1..] {
            acc = b.and2(acc, i);
        }
        b.output(acc);
        let net = b.finish();
        assert_eq!(depth(&net), 8);
    }

    #[test]
    fn tree_depth_is_logarithmic() {
        let mut b = NetBuilder::new("tree");
        let ins = b.inputs(16);
        let o = b.or_tree(&ins);
        b.output(o);
        let net = b.finish();
        assert_eq!(depth(&net), 4);
    }

    #[test]
    fn xor_and_mux_cost_two_levels() {
        let mut b = NetBuilder::new("x");
        let ins = b.inputs(3);
        let x = b.xor2(ins[0], ins[1]);
        let m = b.mux2(ins[2], x, ins[0]);
        b.output(m);
        let net = b.finish();
        assert_eq!(depth(&net), 4);
    }
}
