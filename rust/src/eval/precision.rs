//! Precision-schedule sweep — the serving-side payoff of the run-time
//! repacking unit (Section III-C: "changing the bitwidth of sub-words at
//! run-time dynamically").
//!
//! One fixed 3-layer MLP is compiled under several per-layer precision
//! schedules and a batch is pushed through the packed engine under each;
//! the table reports exact Stage-1/Stage-2 work and pre-characterized
//! energy per inference, with the packed result checked bit-exactly
//! against the scalar mixed-precision oracle first. The low-precision-
//! first schedules pack more batch rows per word in the early (wide)
//! layers, which is where the multiply volume is — that is the energy
//! and throughput story the sweep quantifies.

use crate::anyhow;
use crate::coordinator::cost::CostTable;
use crate::coordinator::engine::PackedEngine;
use crate::coordinator::model::CompiledModel;
use crate::energy::report::table;
use crate::nn::exec::mlp_forward_row_mixed;
use crate::nn::weights::{LayerPrecision, QuantLayer};
use crate::workload::synth::XorShift64;

/// Batch size of the sweep (a multiple of every schedule's quantum).
pub const BATCH: usize = 48;

/// One sweep cell: exact work and billed energy per inference.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub name: &'static str,
    pub schedule: Vec<LayerPrecision>,
    pub s1_cycles_per_row: f64,
    pub s2_passes_per_row: f64,
    pub s1_pj_per_row: f64,
    pub total_pj_per_row: f64,
}

/// The swept schedules over a 3-layer stack: uniform 8-bit, a 4-bit-
/// first widening schedule, and a 16-bit-first narrowing one whose
/// 16→4 boundary exercises the 2-hop crossbar chain.
pub fn schedules() -> Vec<(&'static str, Vec<LayerPrecision>)> {
    vec![
        (
            "8-8-8 (uniform)",
            vec![
                LayerPrecision::new(8, 16),
                LayerPrecision::new(8, 16),
                LayerPrecision::new(8, 16),
            ],
        ),
        (
            "4-6-8 (low first)",
            vec![
                LayerPrecision::new(4, 8),
                LayerPrecision::new(6, 12),
                LayerPrecision::new(8, 16),
            ],
        ),
        (
            "16-8-4 (2-hop 16\u{2192}4)",
            vec![
                LayerPrecision::new(16, 16),
                LayerPrecision::new(8, 16),
                LayerPrecision::new(4, 8),
            ],
        ),
    ]
}

/// The fixed model under sweep: 24→16→12→8, 8-bit weights.
pub fn model_layers() -> Vec<QuantLayer> {
    let mut rng = XorShift64::new(0x5C4ED);
    [(24usize, 16usize), (16, 12), (12, 8)]
        .iter()
        .map(|&(k, n)| {
            QuantLayer::new(
                (0..k)
                    .map(|_| (0..n).map(|_| rng.q_raw(8)).collect())
                    .collect(),
                8,
            )
        })
        .collect()
}

/// Run every schedule; each row is oracle-verified before being priced.
pub fn rows(cost: &CostTable) -> anyhow::Result<Vec<SweepRow>> {
    let layers = model_layers();
    let mut rng = XorShift64::new(0x5C4EE);
    let mut out = vec![];
    for (name, sched) in schedules() {
        let model = CompiledModel::compile_scheduled(layers.clone(), sched.clone())?;
        let engine = PackedEngine::new(model);
        let batch: Vec<Vec<i64>> = (0..BATCH)
            .map(|_| (0..layers[0].k).map(|_| rng.q_raw(sched[0].in_bits)).collect())
            .collect();
        let (got, stats) = engine.forward_batch(&batch);
        for (b, row) in batch.iter().enumerate() {
            let want = mlp_forward_row_mixed(row, &layers, &sched);
            anyhow::ensure!(
                got[b] == want,
                "schedule `{name}` row {b} diverges from the scalar oracle"
            );
        }
        let s1_pj = cost.s1_energy_pj(&stats);
        let total_pj = cost.batch_energy_pj(&stats);
        out.push(SweepRow {
            name,
            schedule: sched,
            s1_cycles_per_row: stats.s1_cycles as f64 / BATCH as f64,
            s2_passes_per_row: stats.s2_passes as f64 / BATCH as f64,
            s1_pj_per_row: s1_pj / BATCH as f64,
            total_pj_per_row: total_pj / BATCH as f64,
        });
    }
    Ok(out)
}

pub fn run() -> anyhow::Result<()> {
    println!(
        "== precision-schedule sweep: per-layer formats on the serving engine \
         ({BATCH}-row batch, @1GHz) =="
    );
    let cost = CostTable::characterize(1000.0);
    let rs = rows(&cost)?;
    let trows: Vec<Vec<String>> = rs
        .iter()
        .map(|r| {
            vec![
                r.name.to_string(),
                r.schedule
                    .iter()
                    .map(|p| p.to_string())
                    .collect::<Vec<_>>()
                    .join(" "),
                format!("{:.1}", r.s1_cycles_per_row),
                format!("{:.1}", r.s2_passes_per_row),
                format!("{:.2}", r.s1_pj_per_row),
                format!("{:.2}", r.total_pj_per_row),
            ]
        })
        .collect();
    println!(
        "{}",
        table(
            &[
                "schedule",
                "layer formats (in->acc)",
                "S1 cyc/row",
                "S2 pass/row",
                "S1 pJ/row",
                "total pJ/row",
            ],
            &trows
        )
    );
    let uniform = &rs[0];
    let low_first = &rs[1];
    println!(
        "(every schedule bit-exact vs the scalar oracle; 4-6-8 spends \
         {:.1}% of the uniform schedule's Stage-1 energy)\n",
        low_first.s1_pj_per_row / uniform.s1_pj_per_row * 100.0
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn low_precision_first_schedule_is_cheaper_on_stage1() {
        // The acceptance claim: the 4-bit-first schedule packs 12 rows
        // per word in the widest layer (vs 6 at 8-bit), so its Stage-1
        // energy per inference undercuts the uniform 8-bit schedule.
        let cost = CostTable::characterize(1000.0);
        let rs = rows(&cost).unwrap();
        let uniform = rs.iter().find(|r| r.name.starts_with("8-8-8")).unwrap();
        let low = rs.iter().find(|r| r.name.starts_with("4-6-8")).unwrap();
        assert!(
            low.s1_pj_per_row < uniform.s1_pj_per_row,
            "4-6-8 {} pJ !< 8-8-8 {} pJ",
            low.s1_pj_per_row,
            uniform.s1_pj_per_row
        );
        assert!(
            low.s1_cycles_per_row < uniform.s1_cycles_per_row,
            "cycle count must also drop"
        );
    }

    #[test]
    fn sweep_covers_a_two_hop_schedule() {
        let two_hop = schedules()
            .into_iter()
            .find(|(n, _)| n.starts_with("16-8-4"))
            .unwrap()
            .1;
        let layers = model_layers();
        let m = CompiledModel::compile_scheduled(layers, two_hop).unwrap();
        assert_eq!(m.boundary_chain(1).len(), 2, "16→4 must chain via 8");
    }
}
