//! The Stage-2 repacking crossbar (Section III-C, Fig. 5) as a netlist.
//!
//! For every supported configuration — each direct conversion hop ×
//! output-word index, plus bypass — each of the 48 output bits has a
//! fixed source bit in the 96-bit `R2:R3` window (or constant 0 for
//! widening zero-fill). The netlist is a per-output one-hot mux over
//! the configuration set; its depth is logarithmic in the config count,
//! which is why Stage-2 area stays flat across timing constraints
//! (Fig. 6 discussion).

use super::build::NetBuilder;
use super::gate::{Netlist, NodeId};
use crate::bits::format::SimdFormat;
use crate::pipeline::stage2::{is_direct, output_words_per_input};

/// One crossbar configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct XbarConfig {
    pub from: SimdFormat,
    pub to: SimdFormat,
    pub in_skip: u32,
    pub bypass: bool,
}

/// Enumerate every configuration the Stage-2 instruction set can issue:
/// bypass first, then each direct hop with every in-window skip.
pub fn config_table() -> Vec<XbarConfig> {
    let mut cfgs = vec![XbarConfig {
        from: SimdFormat::new(8),
        to: SimdFormat::new(8),
        in_skip: 0,
        bypass: true,
    }];
    for from in SimdFormat::all() {
        for to in SimdFormat::all() {
            if from == to || !is_direct(from, to) {
                continue;
            }
            let skips = if to.bits > from.bits {
                output_words_per_input(from, to)
            } else {
                1
            };
            for w in 0..skips {
                cfgs.push(XbarConfig {
                    from,
                    to,
                    in_skip: w * to.lanes(),
                    bypass: false,
                });
            }
        }
    }
    cfgs
}

/// Source window bit for output bit `j` under `cfg`; `None` = constant 0
/// (widening zero-fill).
pub fn source_bit(cfg: &XbarConfig, j: u32) -> Option<u32> {
    if cfg.bypass {
        return Some(j);
    }
    let (b1, b2) = (cfg.from.bits, cfg.to.bits);
    let lane = j / b2;
    let off = j % b2;
    let src_sub = cfg.in_skip + lane;
    if b2 > b1 {
        // Widening: value goes to the top b1 bits of the wider slot.
        let pad = b2 - b1;
        if off < pad {
            None
        } else {
            Some(src_sub * b1 + (off - pad))
        }
    } else {
        // Narrowing: keep the top b2 bits.
        Some(src_sub * b1 + (off + (b1 - b2)))
    }
}

/// Build the crossbar netlist.
/// Inputs: window[96], cfg_onehot[#configs]. Outputs: out[48].
pub fn crossbar_netlist() -> (Netlist, Vec<XbarConfig>) {
    let cfgs = config_table();
    let mut b = NetBuilder::new("softsimd_crossbar");
    let window = b.inputs(96);
    let sel = b.inputs(cfgs.len());
    for j in 0..48u32 {
        // Share mux terms between configurations reading the same source
        // bit (what synthesis does): OR the selects per unique source,
        // then one AND per source. Constant-0 sources need no gate.
        let mut by_source: std::collections::BTreeMap<u32, Vec<NodeId>> =
            std::collections::BTreeMap::new();
        for (ci, cfg) in cfgs.iter().enumerate() {
            if let Some(src) = source_bit(cfg, j) {
                debug_assert!(src < 96, "source beyond window for {cfg:?} bit {j}");
                by_source.entry(src).or_default().push(sel[ci]);
            }
        }
        let terms: Vec<NodeId> = by_source
            .into_iter()
            .map(|(src, sels)| {
                let s = b.or_tree(&sels);
                b.and2(s, window[src as usize])
            })
            .collect();
        let out = b.or_tree(&terms);
        b.output(out);
    }
    (b.finish(), cfgs)
}

/// Drive the crossbar for one cycle.
pub fn drive_crossbar(
    sim: &mut super::sim::Simulator,
    net: &Netlist,
    cfgs: &[XbarConfig],
    window: u128,
    want: &XbarConfig,
) -> u64 {
    let mut ins = Vec::with_capacity(96 + cfgs.len());
    for i in 0..96 {
        ins.push((window >> i) & 1 != 0);
    }
    for cfg in cfgs {
        ins.push(cfg == want);
    }
    sim.set_inputs(&ins);
    sim.eval(net);
    sim.output_u64(net, 0, 48)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::stage2::crossbar_pass;
    use crate::rtl::sim::Simulator;
    use crate::rtl::timing::depth;
    use crate::workload::synth::XorShift64;

    #[test]
    fn config_table_is_complete_and_windowed() {
        let cfgs = config_table();
        assert!(cfgs.len() >= 20, "found {} configs", cfgs.len());
        for cfg in &cfgs {
            for j in 0..48 {
                if let Some(src) = source_bit(cfg, j) {
                    assert!(src < 96, "{cfg:?} bit {j} reads bit {src}");
                }
            }
        }
    }

    #[test]
    fn netlist_matches_functional_crossbar() {
        let (net, cfgs) = crossbar_netlist();
        let mut sim = Simulator::new(&net);
        let mut rng = XorShift64::new(0xCB0B);
        for cfg in &cfgs {
            for _ in 0..25 {
                let window =
                    (rng.word() as u128) | ((rng.word() as u128) << 48);
                let got = drive_crossbar(&mut sim, &net, &cfgs, window, cfg);
                let want = if cfg.bypass {
                    (window & ((1u128 << 48) - 1)) as u64
                } else {
                    crossbar_pass(window, cfg.from, cfg.to, cfg.in_skip)
                };
                assert_eq!(got, want, "{cfg:?}");
            }
        }
    }

    #[test]
    fn crossbar_is_shallow() {
        let (net, _) = crossbar_netlist();
        // Logarithmic in config count: well under 20 levels.
        assert!(depth(&net) < 20, "depth {}", depth(&net));
    }
}
