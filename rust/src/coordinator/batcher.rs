//! Dynamic batching: group inference requests into packed batches.
//!
//! Soft SIMD packs the batch dimension into sub-words, so the natural
//! batch quantum is a multiple of the model's per-layer lane counts
//! (`CompiledModel::batch_quantum`; 6 for the uniform 8→16 schedule) —
//! the engine pads the remainder with zero rows (DESIGN.md §8). The batcher
//! accumulates requests until it can fill `target_rows` rows or a flush
//! is forced; starvation is prevented by the coordinator's deadline
//! thread, which drives [`Batcher::tick`] at a fixed period so
//! stragglers flush without an explicit `drain()` — the classic
//! latency/throughput dial of serving systems.
//!
//! With run-time precision variants (DESIGN.md §13) the quantum is a
//! property of the *active variant*: the governor re-arms it via
//! [`Batcher::set_quantum`] after every decision, and push-path batches
//! prefer a whole-request cut whose row total is a multiple of the
//! quantum (fewer zero pad rows at the engine) — deadline and drain
//! flushes still take everything, and a quantum change never drops,
//! splits or duplicates a pending request (the mid-stream-switch
//! property test pins it).
//!
//! **Restore/retry semantics.** A batch whose dispatch failed is handed
//! back via [`Batcher::restore`]; its rows go to the front of the queue
//! *and the retry is armed*: the very next [`Batcher::tick`] flushes,
//! regardless of the idle-poll deadline. Restored rows already waited
//! out their deadline once — making them sit through a second full
//! idle-poll cycle (the pre-fix behavior, worse when the restored rows
//! already meet `target_rows` and no new arrival will ever trigger a
//! push-path flush) would silently double their latency.

use std::time::Instant;

use super::server::Request;

/// A request stamped with its arrival time (for latency percentiles).
#[derive(Debug)]
pub struct TrackedRequest {
    pub req: Request,
    pub submitted_at: Instant,
}

impl TrackedRequest {
    pub fn now(req: Request) -> Self {
        TrackedRequest { req, submitted_at: Instant::now() }
    }
}

/// A formed batch: requests plus the row span each owns, tagged with
/// the precision variant it should execute at (assigned by the
/// governor at dispatch; 0 — the reference variant — at formation).
#[derive(Debug)]
pub struct Batch {
    pub entries: Vec<TrackedRequest>,
    pub rows: usize,
    /// Precision variant this batch executes at. The worker bills this
    /// — the variant actually executed — never a later decision.
    pub variant: usize,
    /// Tenant class whose lane formed this batch (fleet serving,
    /// DESIGN.md §17). Lanes are per-tenant, so a batch is always
    /// tenant-homogeneous; the worker bills the tenant's bucket and
    /// tags every response with it. 0 — the only class — for the
    /// single-tenant `Coordinator`.
    pub tenant: usize,
}

/// Row-count batcher.
#[derive(Debug)]
pub struct Batcher {
    pending: Vec<TrackedRequest>,
    pending_rows: usize,
    pub target_rows: usize,
    pub max_wait_polls: u32,
    idle_polls: u32,
    /// Set by [`Batcher::restore`]: the pending rows came back from a
    /// failed dispatch, so the next tick flushes immediately instead of
    /// waiting out another full idle-poll deadline. Disarmed as soon as
    /// no restored row remains pending (`restored_pending`), so a
    /// successful re-dispatch does not leak an early flush to fresh
    /// stragglers that never failed.
    retry_armed: bool,
    /// Rows currently pending that came back via [`Batcher::restore`].
    /// Restores prepend and every emission takes a queue prefix, so
    /// restored rows always leave before fresh ones — subtracting each
    /// emitted batch's rows (saturating) tracks them exactly.
    restored_pending: usize,
    /// The active variant's batch quantum (rows per full packed word
    /// set). Push-path batches prefer a row total that is a multiple of
    /// this so the engine pads as few zero rows as possible; deadline
    /// and drain flushes still take everything (latency beats lane
    /// occupancy for stragglers). 1 = no alignment preference.
    quantum: usize,
}

impl Batcher {
    pub fn new(target_rows: usize, max_wait_polls: u32) -> Self {
        Batcher {
            pending: vec![],
            pending_rows: 0,
            target_rows: target_rows.max(1),
            max_wait_polls: max_wait_polls.max(1),
            idle_polls: 0,
            retry_armed: false,
            restored_pending: 0,
            quantum: 1,
        }
    }

    pub fn pending_rows(&self) -> usize {
        self.pending_rows
    }

    /// Update the lane-padding quantum to the active variant's
    /// (DESIGN.md §13). Takes effect for the *next* formed batch; rows
    /// already pending are never dropped or split by a quantum change —
    /// the mid-stream-switch property test pins exactly-once emission
    /// across arbitrary switch points.
    pub fn set_quantum(&mut self, quantum: usize) {
        self.quantum = quantum.max(1);
    }

    pub fn quantum(&self) -> usize {
        self.quantum
    }

    /// Offer a request; returns a formed batch when the target fills.
    /// The formed batch is the shortest request prefix reaching the
    /// target, extended (by whole requests — a request's rows are never
    /// split across batches) until its row total hits a multiple of the
    /// active quantum; when no aligned cut exists the whole queue goes
    /// out and the engine pads the remainder.
    pub fn push(&mut self, tr: TrackedRequest) -> Option<Batch> {
        self.pending_rows += tr.req.rows.len();
        self.pending.push(tr);
        self.idle_polls = 0;
        if self.pending_rows >= self.target_rows {
            return self.form_aligned();
        }
        None
    }

    /// The push-path batch former: shortest prefix ≥ target, extended
    /// to quantum alignment, whole queue as the fallback.
    fn form_aligned(&mut self) -> Option<Batch> {
        let mut rows = 0usize;
        let mut cut = self.pending.len();
        for (i, tr) in self.pending.iter().enumerate() {
            rows += tr.req.rows.len();
            if rows >= self.target_rows {
                cut = i + 1;
                break;
            }
        }
        while rows % self.quantum != 0 && cut < self.pending.len() {
            rows += self.pending[cut].req.rows.len();
            cut += 1;
        }
        if cut == self.pending.len() {
            return self.flush();
        }
        self.idle_polls = 0;
        let entries: Vec<TrackedRequest> = self.pending.drain(..cut).collect();
        self.pending_rows -= rows;
        // Restored rows sit at the queue front, so this prefix carries
        // them out first; once none remain the armed retry is spent —
        // fresh stragglers left behind follow normal deadline pacing.
        self.restored_pending = self.restored_pending.saturating_sub(rows);
        if self.restored_pending == 0 {
            self.retry_armed = false;
        }
        debug_assert_eq!(rows, entries.iter().map(|e| e.req.rows.len()).sum::<usize>());
        Some(Batch { entries, rows, variant: 0, tenant: 0 })
    }

    /// Put a formed batch back (dispatch failed); its rows go to the
    /// front of the queue and the retry is armed: the next [`tick`]
    /// re-flushes immediately — restored rows never wait out a second
    /// idle-poll deadline (and a new `push` does not disarm the retry;
    /// arrivals must not reset a failed dispatch's clock).
    ///
    /// [`tick`]: Batcher::tick
    pub fn restore(&mut self, batch: Batch) {
        self.pending_rows += batch.rows;
        self.restored_pending += batch.rows;
        let mut entries = batch.entries;
        entries.append(&mut self.pending);
        self.pending = entries;
        self.retry_armed = true;
    }

    /// Poll tick with no arrivals; flushes after `max_wait_polls` idle
    /// ticks so stragglers are not starved — or immediately when a
    /// restored batch armed the retry.
    pub fn tick(&mut self) -> Option<Batch> {
        if self.pending.is_empty() {
            return None;
        }
        self.idle_polls += 1;
        if self.retry_armed || self.idle_polls >= self.max_wait_polls {
            self.flush()
        } else {
            None
        }
    }

    /// Force out whatever is queued.
    pub fn flush(&mut self) -> Option<Batch> {
        if self.pending.is_empty() {
            return None;
        }
        self.idle_polls = 0;
        self.retry_armed = false;
        self.restored_pending = 0;
        let entries = std::mem::take(&mut self.pending);
        let rows = std::mem::take(&mut self.pending_rows);
        Some(Batch { entries, rows, variant: 0, tenant: 0 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, rows: usize) -> TrackedRequest {
        TrackedRequest::now(Request { id, rows: vec![vec![0i64; 4]; rows] })
    }

    #[test]
    fn fills_to_target() {
        let mut b = Batcher::new(6, 4);
        assert!(b.push(req(1, 2)).is_none());
        assert!(b.push(req(2, 2)).is_none());
        let batch = b.push(req(3, 2)).expect("target reached");
        assert_eq!(batch.rows, 6);
        assert_eq!(batch.entries.len(), 3);
        assert_eq!(b.pending_rows(), 0);
    }

    #[test]
    fn deadline_flush_prevents_starvation() {
        let mut b = Batcher::new(6, 3);
        assert!(b.push(req(1, 1)).is_none());
        assert!(b.tick().is_none());
        assert!(b.tick().is_none());
        let batch = b.tick().expect("deadline flush");
        assert_eq!(batch.rows, 1);
    }

    #[test]
    fn oversized_request_flushes_immediately() {
        let mut b = Batcher::new(4, 3);
        let batch = b.push(req(1, 9)).expect("flush");
        assert_eq!(batch.rows, 9);
    }

    #[test]
    fn empty_tick_is_noop() {
        let mut b = Batcher::new(4, 1);
        assert!(b.tick().is_none());
        assert!(b.flush().is_none());
    }

    #[test]
    fn restore_requeues_without_loss() {
        let mut b = Batcher::new(4, 2);
        let batch = b.push(req(1, 5)).expect("flush");
        assert!(b.push(req(2, 1)).is_none());
        b.restore(batch);
        assert_eq!(b.pending_rows(), 6);
        let again = b.flush().expect("restored rows flush");
        assert_eq!(again.rows, 6);
        assert_eq!(again.entries[0].req.id, 1, "restored batch goes first");
    }

    #[test]
    fn restore_arms_immediate_retry_on_next_tick() {
        // Regression: a restored batch used to wait out a *second* full
        // idle-poll deadline — and when its rows already met
        // `target_rows`, no later push would ever flush it either. Now
        // the tick after a restore flushes unconditionally.
        let mut b = Batcher::new(4, 3);
        let batch = b.push(req(1, 4)).expect("target reached");
        b.restore(batch);
        let retried = b.tick().expect("first tick after restore must flush");
        assert_eq!(retried.rows, 4);
        assert_eq!(retried.entries[0].req.id, 1);
        // The retry is one-shot: normal deadline pacing resumes after.
        assert!(b.push(req(2, 1)).is_none());
        assert!(b.tick().is_none(), "tick 1 of 3 must wait");
        assert!(b.tick().is_none(), "tick 2 of 3 must wait");
        assert!(b.tick().is_some(), "deadline flush on tick 3");
    }

    #[test]
    fn dispatch_fail_then_worker_recovery_retries_on_next_tick() {
        // The serving sequence the fix exists for: a formed batch's
        // dispatch fails (all workers busy/dead), the batcher takes the
        // rows back, the worker pool recovers, and the *next* deadline
        // tick — not a full extra deadline cycle later — re-flushes the
        // same rows for a successful dispatch.
        let mut b = Batcher::new(6, 4);
        let mut worker_up = false;
        let mut served: Vec<u64> = vec![];
        // Requests arrive and fill the target: a batch forms.
        assert!(b.push(req(7, 3)).is_none());
        let batch = b.push(req(8, 3)).expect("target reached");
        // Dispatch fails — the worker is down; the rows are restored.
        assert!(!worker_up);
        b.restore(batch);
        assert_eq!(b.pending_rows(), 6, "no row may be lost on restore");
        // The worker recovers before the next deadline tick.
        worker_up = true;
        // That next tick retries immediately (with the bug it returned
        // None here, and for a target-met batch with no further
        // arrivals the rows sat a whole extra deadline cycle).
        let retry = b.tick().expect("immediate retry on the tick after restore");
        assert!(worker_up, "recovered worker takes the batch");
        served.extend(retry.entries.iter().map(|e| e.req.id));
        assert_eq!(served, vec![7, 8], "same rows, same order, exactly once");
        assert_eq!(b.pending_rows(), 0);
    }

    #[test]
    fn push_forms_quantum_aligned_batches_when_a_cut_exists() {
        // target 4, quantum 6: a restored 6-row batch plus a 1-row
        // straggler re-forms as the aligned 6-row cut, leaving the
        // straggler pending instead of dragging a 7-row batch (1 row of
        // which the engine would pad to 12) out the door.
        let mut b = Batcher::new(4, 3);
        b.set_quantum(6);
        assert_eq!(b.quantum(), 6);
        assert!(b.push(req(1, 3)).is_none());
        let a = b.push(req(2, 3)).expect("target reached");
        assert_eq!(a.rows, 6);
        b.restore(a);
        let aligned = b.push(req(3, 1)).expect("restored rows re-form");
        assert_eq!(aligned.rows, 6, "aligned cut leaves the straggler pending");
        assert_eq!(aligned.entries.len(), 2);
        assert_eq!(b.pending_rows(), 1);
        // No aligned cut exists → the whole queue goes out and the
        // engine pads the remainder (alignment is a preference, never a
        // reason to strand rows).
        let mut c = Batcher::new(4, 3);
        c.set_quantum(5);
        assert!(c.push(req(4, 3)).is_none());
        let all = c.push(req(5, 3)).expect("target");
        assert_eq!(all.rows, 6, "misaligned: take everything");
        assert_eq!(c.pending_rows(), 0);
        // Formed batches default to the reference variant until the
        // governor re-tags them at dispatch.
        assert_eq!(all.variant, 0);
    }

    #[test]
    fn successful_redispatch_of_restored_rows_disarms_the_retry() {
        // Regression (stale retry_armed): once a push-path cut carries
        // every restored row back out, a fresh straggler left pending
        // must wait out the normal deadline — not inherit the failed
        // dispatch's immediate-flush flag.
        let mut b = Batcher::new(4, 3);
        let a = b.push(req(1, 4)).expect("target reached");
        assert!(b.push(req(2, 1)).is_none(), "fresh straggler pends");
        b.restore(a);
        // The next push re-forms a batch; the aligned prefix is exactly
        // the restored rows (4 ≥ target), leaving [2, 3] pending.
        let retried = b.push(req(3, 1)).expect("restored rows re-form");
        assert_eq!(retried.entries[0].req.id, 1, "restored rows go first");
        assert_eq!(b.pending_rows(), 2);
        assert!(b.tick().is_none(), "tick 1 of 3: retry is spent");
        assert!(b.tick().is_none(), "tick 2 of 3");
        let late = b.tick().expect("deadline flush on tick 3");
        assert_eq!(late.rows, 2);
        // But while *any* restored row remains pending, the retry stays
        // armed: a partial cut must not strand the rest of a restored
        // batch behind a fresh deadline.
        let mut c = Batcher::new(2, 3);
        assert!(c.push(req(10, 2)).is_some());
        let big = Batch {
            entries: vec![req(11, 2), req(12, 2)],
            rows: 4,
            variant: 0,
            tenant: 0,
        };
        c.restore(big);
        let first = c.push(req(13, 1)).expect("re-form");
        assert_eq!(first.entries[0].req.id, 11);
        assert_eq!(first.rows, 2, "partial cut: one restored entry left");
        let rest = c.tick().expect("armed retry flushes the remaining restored rows");
        assert_eq!(rest.entries[0].req.id, 12);
    }

    #[test]
    fn prop_mid_stream_quantum_switches_preserve_rows_and_exactly_once() {
        // The §13 satellite property: under arbitrary interleavings of
        // push / tick / flush / restore *and quantum switches between
        // them* (the governor changing the active variant mid-stream),
        // `pending_rows()` always equals the sum of the pending
        // entries' row counts, push-path batches are quantum-aligned
        // unless they emptied the queue, and every pushed request is
        // emitted exactly once.
        use crate::workload::synth::XorShift64;
        let mut rng = XorShift64::new(0x9A27B1);
        let quanta = [1usize, 4, 6, 12, 24];
        for case in 0..60 {
            let target = 1 + (rng.next_u64() % 12) as usize;
            let max_polls = 1 + (rng.next_u64() % 4) as u32;
            let mut b = Batcher::new(target, max_polls);
            let mut next_id = 0u64;
            let mut expected_pending = 0usize;
            let mut limbo: Vec<Batch> = vec![];
            let mut done: Vec<u64> = vec![];
            let mut pushed: Vec<u64> = vec![];
            for _ in 0..300 {
                match rng.next_u64() % 12 {
                    0..=5 => {
                        let rows = 1 + (rng.next_u64() % 5) as usize;
                        let id = next_id;
                        next_id += 1;
                        pushed.push(id);
                        expected_pending += rows;
                        if let Some(batch) = b.push(req(id, rows)) {
                            assert!(
                                batch.rows % b.quantum() == 0 || b.pending_rows() == 0,
                                "case {case}: unaligned cut left rows pending \
                                 (quantum {}, batch {})",
                                b.quantum(),
                                batch.rows
                            );
                            expected_pending -= batch.rows;
                            limbo.push(batch);
                        }
                    }
                    6..=7 => {
                        if let Some(batch) = b.tick() {
                            expected_pending -= batch.rows;
                            limbo.push(batch);
                        }
                    }
                    8 => {
                        if let Some(batch) = b.flush() {
                            expected_pending -= batch.rows;
                            limbo.push(batch);
                        }
                    }
                    // The governor switches the active variant between
                    // ticks: the quantum changes under pending rows.
                    9 => {
                        let q = quanta[(rng.next_u64() % quanta.len() as u64) as usize];
                        b.set_quantum(q);
                    }
                    _ => {
                        if !limbo.is_empty() {
                            let i = (rng.next_u64() % limbo.len() as u64) as usize;
                            let batch = limbo.swap_remove(i);
                            if rng.next_u64() % 2 == 0 {
                                expected_pending += batch.rows;
                                b.restore(batch);
                            } else {
                                done.extend(batch.entries.iter().map(|e| e.req.id));
                            }
                        }
                    }
                }
                assert_eq!(
                    b.pending_rows(),
                    expected_pending,
                    "case {case}: pending_rows drifted from the entry sum"
                );
            }
            if let Some(batch) = b.flush() {
                expected_pending -= batch.rows;
                limbo.push(batch);
            }
            assert_eq!(expected_pending, 0, "case {case}");
            assert_eq!(b.pending_rows(), 0, "case {case}");
            for batch in limbo.drain(..) {
                assert_eq!(
                    batch.rows,
                    batch.entries.iter().map(|e| e.req.rows.len()).sum::<usize>(),
                    "case {case}: batch rows must equal its entries' rows"
                );
                done.extend(batch.entries.iter().map(|e| e.req.id));
            }
            done.sort_unstable();
            pushed.sort_unstable();
            assert_eq!(
                done, pushed,
                "case {case}: every request exactly once — none dropped, none duplicated"
            );
        }
    }

    #[test]
    fn prop_interleaved_push_tick_flush_restore_preserve_rows_and_requests() {
        // Property: under arbitrary interleavings of push / tick /
        // flush / restore, `pending_rows()` always equals the sum of
        // the pending entries' row counts, and every pushed request is
        // emitted exactly once (no loss, no duplication) once
        // everything is drained.
        use crate::workload::synth::XorShift64;
        let mut rng = XorShift64::new(0xBA7C4E5);
        for case in 0..60 {
            let target = 1 + (rng.next_u64() % 9) as usize;
            let max_polls = 1 + (rng.next_u64() % 4) as u32;
            let mut b = Batcher::new(target, max_polls);
            let mut next_id = 0u64;
            let mut expected_pending = 0usize; // rows inside the batcher
            let mut limbo: Vec<Batch> = vec![]; // emitted, restorable
            let mut done: Vec<u64> = vec![]; // ids emitted for good
            let mut pushed: Vec<u64> = vec![];
            let mut note = |batch: Option<Batch>,
                            expected_pending: &mut usize,
                            limbo: &mut Vec<Batch>| {
                if let Some(batch) = batch {
                    assert_eq!(
                        batch.rows,
                        batch
                            .entries
                            .iter()
                            .map(|e| e.req.rows.len())
                            .sum::<usize>(),
                        "case {case}: batch rows must equal its entries' rows"
                    );
                    *expected_pending -= batch.rows;
                    limbo.push(batch);
                }
            };
            for _ in 0..200 {
                match rng.next_u64() % 10 {
                    // push (weighted): 1–3 rows per request.
                    0..=4 => {
                        let rows = 1 + (rng.next_u64() % 3) as usize;
                        let id = next_id;
                        next_id += 1;
                        pushed.push(id);
                        expected_pending += rows;
                        note(b.push(req(id, rows)), &mut expected_pending, &mut limbo);
                    }
                    5..=6 => note(b.tick(), &mut expected_pending, &mut limbo),
                    7 => note(b.flush(), &mut expected_pending, &mut limbo),
                    // restore a random in-limbo batch, or settle it.
                    _ => {
                        if !limbo.is_empty() {
                            let i = (rng.next_u64() % limbo.len() as u64) as usize;
                            let batch = limbo.swap_remove(i);
                            if rng.next_u64() % 2 == 0 {
                                expected_pending += batch.rows;
                                b.restore(batch);
                            } else {
                                done.extend(batch.entries.iter().map(|e| e.req.id));
                            }
                        }
                    }
                }
                assert_eq!(
                    b.pending_rows(),
                    expected_pending,
                    "case {case}: pending_rows drifted from the entry sum"
                );
            }
            // Drain: final flush plus every unsettled in-limbo batch.
            note(b.flush(), &mut expected_pending, &mut limbo);
            assert_eq!(b.pending_rows(), 0);
            assert_eq!(expected_pending, 0);
            for batch in limbo.drain(..) {
                done.extend(batch.entries.iter().map(|e| e.req.id));
            }
            done.sort_unstable();
            pushed.sort_unstable();
            assert_eq!(
                done, pushed,
                "case {case}: every request exactly once — none dropped, none duplicated"
            );
        }
    }
}
